"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode (CPU container; TPU is the lowering target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_swiglu.kernel import fused_swiglu_pallas
from repro.kernels.fused_swiglu.ref import swiglu_ref
from repro.kernels.mlstm_scan.ops import mlstm_scan
from repro.kernels.mlstm_scan.ref import mlstm_ref
from repro.kernels.ssm_scan.ops import ssd_scan
from repro.kernels.ssm_scan.ref import ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, hq, hkv, sq, skv, d, causal, block_q, block_kv)
    (1, 2, 2, 128, 128, 64, True, 64, 64),
    (2, 4, 2, 256, 256, 64, True, 128, 128),     # GQA 2:1
    (1, 8, 1, 128, 128, 128, True, 64, 64),      # MQA
    (1, 2, 2, 200, 200, 64, True, 64, 64),       # ragged seq (padding)
    (1, 2, 2, 128, 256, 64, False, 64, 128),     # cross attention
    (2, 2, 2, 256, 256, 32, True, 256, 256),     # single block
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, hq, hkv, sq, skv, d, causal, bq, bkv = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (b, hq, sq, d), dtype)
    k = jax.random.normal(kk, (b, hkv, skv, d), dtype)
    v = jax.random.normal(kv, (b, hkv, skv, d), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                              block_kv=bkv, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grad_matches_ref():
    rng = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (1, 2, 128, 64), jnp.float32)
    k = jax.random.normal(kk, (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(kv, (1, 2, 128, 64), jnp.float32)

    def f_kernel(q, k, v):
        # ops-layer API takes (B, S, H, D)
        return jnp.sum(flash_attention(q.transpose(0, 2, 1, 3),
                                       k.transpose(0, 2, 1, 3),
                                       v.transpose(0, 2, 1, 3),
                                       block_q=64, block_kv=64) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# SSD / mamba2 scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, h, p, n, chunk)
    (1, 64, 2, 16, 16, 32),
    (2, 128, 4, 32, 64, 64),
    (1, 100, 2, 16, 16, 32),      # ragged
    (1, 32, 1, 64, 32, 32),       # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_sequential_ref(case):
    b, s, h, p, n, chunk = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.normal(ks[2], (h,)) * 0.5
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    out = ssd_scan(x, dt, A_log, B, C, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_models_module_matches_ref():
    """The jnp ssd_chunked inside models/ssm.py agrees with the oracle too."""
    from repro.models.ssm import ssd_chunked
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 5)
    b, s, h, p, n = 2, 96, 2, 16, 32
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.normal(ks[2], (h,)) * 0.5
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    out = ssd_chunked(x, dt, A_log, B, C, chunk=32)
    ref = ssd_ref(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mLSTM scan
# ---------------------------------------------------------------------------

MLSTM_CASES = [
    # (b, s, h, p, chunk)
    (1, 64, 2, 16, 32),
    (2, 128, 4, 32, 64),
    (1, 100, 2, 16, 32),          # ragged
    (1, 32, 1, 64, 32),
]


@pytest.mark.parametrize("case", MLSTM_CASES)
def test_mlstm_scan_matches_sequential_ref(case):
    b, s, h, p, chunk = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    ks = jax.random.split(rng, 5)
    q = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, p), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, p), jnp.float32)
    ig = jax.random.normal(ks[3], (b, s, h)) * 2.0
    fg = jax.random.normal(ks[4], (b, s, h)) * 2.0 + 2.0
    out = mlstm_scan(q, k, v, ig, fg, chunk=chunk, interpret=True)
    ref = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_models_module_matches_ref():
    from repro.models.xlstm import mlstm_chunked
    rng = jax.random.PRNGKey(7)
    ks = jax.random.split(rng, 5)
    b, s, h, p = 1, 96, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, p), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, p), jnp.float32)
    ig = jax.random.normal(ks[3], (b, s, h)) * 2.0
    fg = jax.random.normal(ks[4], (b, s, h)) * 2.0 + 2.0
    out = mlstm_chunked(q, k, v, ig, fg, chunk=32)
    ref = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused SwiGLU
# ---------------------------------------------------------------------------

SWIGLU_CASES = [
    # (m, k, f, bm, bf, bk)
    (128, 256, 512, 64, 128, 128),
    (256, 512, 256, 128, 256, 256),
    (100, 200, 300, 64, 128, 128),   # ragged everywhere
    (64, 64, 64, 64, 64, 64),        # single tile
]


@pytest.mark.parametrize("case", SWIGLU_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_swiglu_matches_ref(case, dtype):
    m, k, f, bm, bf, bk = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2, k3 = jax.random.split(rng, 3)
    x = jax.random.normal(k1, (m, k), dtype) * 0.5
    wg = jax.random.normal(k2, (k, f), dtype) * 0.05
    wu = jax.random.normal(k3, (k, f), dtype) * 0.05
    out = fused_swiglu_pallas(x, wg, wu, block_m=bm, block_f=bf, block_k=bk,
                              interpret=True)
    ref = swiglu_ref(x, wg, wu)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
