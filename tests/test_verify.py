"""The static plan verifier: CHECKS registry over hand-built op lists,
the mutation->check-id contract, the compile-time verify knob, backend
admission of verified schedules only, and the runtime sanitizer.
"""

import dataclasses
import importlib.util
import pathlib

import pytest

from repro.core import MemoryPlanConfig, compile_plan
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec
from repro.core.plan import (Compute, ExecutionSchedule, Free, Prefetch,
                             SwapOut)
from repro.core.planner import Placement, Plan
from repro.core.verify import (CHECKS, Diagnostic,
                               ScheduleVerificationError, VerifyReport,
                               is_verified, plan_aliasing_diagnostics,
                               verify_plan, verify_schedule)
from repro.core.zoo import ZOO

_HARNESS_PATH = (pathlib.Path(__file__).resolve().parents[1]
                 / "tools" / "mutate_schedule.py")
_spec = importlib.util.spec_from_file_location("mutate_schedule",
                                               _HARNESS_PATH)
mutate_schedule = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(mutate_schedule)


# ---------------------------------------------------------------------------
# Hand-built op lists: one tensor, one swap window
# ---------------------------------------------------------------------------

class _FakeOrdered:
    def __init__(self, tensors, eo_max=100):
        self.tensors = {t.name: t for t in tensors}
        self.merged = {}
        self.eo_max = eo_max
        self.layer_orders = {}

    def owner(self, name):
        while name in self.merged:
            name = self.merged[name]
        return name

    def planned_tensors(self):
        return [t for t in self.tensors.values()
                if t.create_mode == CreateMode.CREATE]


def _t(name, nbytes, orders):
    t = TensorSpec(name=name, shape=(nbytes,), dtype="uint8",
                   lifespan=Lifespan.FORWARD, create_mode=CreateMode.CREATE)
    t.exec_orders = tuple(sorted(orders))
    return t


def _one_swap_case(orders=(0, 10)):
    """Produce at EO 0, swap out at 1, prefetch at 8 for a read at 10."""
    ordered = _FakeOrdered([_t("X:a", 256, orders)])
    ops = (
        Compute(eo=0, layer="a", kind="F"),
        SwapOut(eo=1, tensor="X:a", nbytes=256, device_offset=-1,
                host_offset=-1),
        Prefetch(eo=8, tensor="X:a", nbytes=256, device_offset=-1,
                 host_offset=-1, read_eo=10),
        Free(eo=10, tensor="X:a", nbytes=256, device_offset=-1),
    )
    return ordered, ExecutionSchedule(ops=ops)


def _verify(ordered, lowered, **kw):
    return verify_schedule(ordered, None, None, lowered, **kw)


def test_valid_hand_built_schedule_has_zero_diagnostics():
    ordered, lowered = _one_swap_case()
    report = _verify(ordered, lowered)
    assert report.ok
    assert report.diagnostics == ()
    assert report.ops_scanned == 4
    assert set(report.checks_run) == set(CHECKS)


def test_read_after_swap_out_without_prefetch_is_use_before_resident():
    ordered, lowered = _one_swap_case()
    ops = tuple(op for op in lowered.ops if not isinstance(op, Prefetch))
    report = _verify(ordered, ExecutionSchedule(ops=ops))
    assert not report.ok
    assert "use_before_resident" in report.check_ids()
    d = next(d for d in report.errors()
             if d.check == "use_before_resident")
    assert d.tensor == "X:a"
    assert "swapped out" in d.message


def test_read_racing_inflight_prefetch_is_use_before_resident():
    # an access at EO 9 lands after the prefetch issued (8) but before its
    # guaranteed completion (read_eo=10): statically a race
    ordered, lowered = _one_swap_case(orders=(0, 9, 10))
    report = _verify(ordered, lowered)
    assert not report.ok
    assert "use_before_resident" in report.check_ids()
    assert any("in-flight prefetch" in d.message for d in report.errors())


def test_prefetch_before_swap_out_retires_is_transfer_race():
    ordered, lowered = _one_swap_case()
    out = next(op for op in lowered.ops if isinstance(op, SwapOut))
    ops = tuple(dataclasses.replace(op, eo=9) if op is out else op
                for op in lowered.ops)
    report = _verify(ordered, ExecutionSchedule(ops=ops))
    assert "transfer_race" in report.check_ids()


def test_overlapping_host_slots_in_live_windows_is_transfer_race():
    ordered = _FakeOrdered([_t("X:a", 256, (0, 10)),
                            _t("X:b", 256, (0, 12))])
    ops = (
        Compute(eo=0, layer="a", kind="F"),
        Compute(eo=0, layer="b", kind="F"),
        # both copies parked at host offset 0 with overlapping windows
        SwapOut(eo=1, tensor="X:a", nbytes=256, device_offset=-1,
                host_offset=0),
        SwapOut(eo=2, tensor="X:b", nbytes=256, device_offset=-1,
                host_offset=0),
        Prefetch(eo=8, tensor="X:a", nbytes=256, device_offset=-1,
                 host_offset=0, read_eo=10),
        Prefetch(eo=9, tensor="X:b", nbytes=256, device_offset=-1,
                 host_offset=0, read_eo=12),
        Free(eo=10, tensor="X:a", nbytes=256, device_offset=-1),
        Free(eo=12, tensor="X:b", nbytes=256, device_offset=-1),
    )
    report = _verify(ordered, ExecutionSchedule(ops=ops))
    assert "transfer_race" in report.check_ids()
    assert any("host slot" in d.message for d in report.errors())


def test_duplicated_free_is_double_free():
    ordered, lowered = _one_swap_case()
    f = next(op for op in lowered.ops if isinstance(op, Free))
    report = _verify(ordered, ExecutionSchedule(ops=lowered.ops + (f,)))
    assert "double_free" in report.check_ids()


def test_dropped_free_is_leak():
    ordered, lowered = _one_swap_case()
    ops = tuple(op for op in lowered.ops if not isinstance(op, Free))
    report = _verify(ordered, ExecutionSchedule(ops=ops))
    assert "leak" in report.check_ids()


def test_unknown_check_name_is_a_clear_valueerror():
    ordered, lowered = _one_swap_case()
    with pytest.raises(ValueError, match="unknown verifier check"):
        _verify(ordered, lowered, checks=("no_such_pass",))


def test_check_subset_runs_only_the_requested_passes():
    ordered, lowered = _one_swap_case()
    ops = tuple(op for op in lowered.ops if not isinstance(op, Free))
    report = _verify(ordered, ExecutionSchedule(ops=ops),
                     checks=("use_before_resident",))
    assert report.checks_run == ("use_before_resident",)
    assert report.ok   # the leak pass did not run


# ---------------------------------------------------------------------------
# Plan.validate() delegation: one aliasing checker, same message shapes
# ---------------------------------------------------------------------------

def test_plan_validate_delegates_overlap_to_the_aliasing_checker():
    plan = Plan({"a": Placement("a", 0, 128, 0, 10),
                 "b": Placement("b", 64, 128, 5, 15)}, 256, "sorting")
    diags = plan_aliasing_diagnostics(plan)
    assert [d.check for d in diags] == ["arena_alias"]
    with pytest.raises(AssertionError, match="overlap: a"):
        plan.validate()


def test_plan_validate_keeps_align_and_arena_messages():
    with pytest.raises(AssertionError, match="ALIGN"):
        Plan({"x": Placement("x", 32, 64, 0, 1)}, 128, "sorting").validate()
    with pytest.raises(AssertionError, match="exceeds arena"):
        Plan({"x": Placement("x", 0, 256, 0, 1)}, 128, "sorting").validate()


# ---------------------------------------------------------------------------
# Mutation harness: every corruption class -> the expected check id
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def reference_cp():
    return mutate_schedule.reference_plan()


def test_reference_plan_verifies_clean(reference_cp):
    report = verify_plan(reference_cp)
    assert report.ok
    assert report.ops_scanned == len(reference_cp.lowered.ops)
    assert report.placements_scanned > 0


@pytest.mark.parametrize("mutation,expected", [
    ("shift_offset", "arena_alias"),
    ("drop_prefetch", "use_before_resident"),
    ("reorder_swap_out", "transfer_race"),
    ("double_free", "double_free"),
    ("truncate_free", "leak"),
    ("budget_overflow", "budget"),
    ("misalign", "alignment"),
])
def test_forged_corruption_is_flagged_with_expected_check_id(
        reference_cp, mutation, expected):
    cp = reference_cp
    forged = mutate_schedule.forge(cp, mutation)
    report = verify_schedule(cp.ordered, cp.schedule, cp.plan, forged)
    assert not report.ok, mutation
    assert expected in report.check_ids(), \
        f"{mutation}: expected {expected}, got {sorted(report.check_ids())}"


def test_harness_main_exits_zero():
    assert mutate_schedule.main() == 0


# ---------------------------------------------------------------------------
# The verify knob on MemoryPlanConfig
# ---------------------------------------------------------------------------

def test_unknown_verify_mode_fails_fast():
    with pytest.raises(ValueError, match="unknown verify mode"):
        compile_plan(ZOO["linear"](),
                     MemoryPlanConfig(verify="strict"), batch=4)


def test_verify_off_skips_the_report():
    cp = compile_plan(ZOO["linear"](),
                      MemoryPlanConfig(verify="off", min_idle_phases=3,
                                       min_bytes=1 << 10), batch=4)
    assert cp.verify_report is None
    assert "verify" not in cp.report()


def test_default_compile_folds_verify_into_report(reference_cp):
    r = reference_cp.report()["verify"]
    assert r["ok"] is True
    assert r["errors"] == 0
    assert set(r["checks_run"]) == set(CHECKS)
    assert r["ops_scanned"] == len(reference_cp.lowered.ops)
    assert r["wall_time_s"] >= 0
    assert is_verified(reference_cp.lowered)


def test_model_path_compile_carries_a_verify_report():
    from repro.configs import ARCHS
    cp = compile_plan(ARCHS["llama3.2-3b"],
                      MemoryPlanConfig(remat=True,
                                       remat_budget_bytes=1 << 20,
                                       offload=True, dma_gbps=80.0,
                                       device_tflops=200.0),
                      batch_tokens=2048)
    r = cp.report()["verify"]
    assert r["ok"] is True
    assert r["checks_run"] == ["budget"]


def test_verification_error_is_an_assertion_error_with_diagnostics():
    err = ScheduleVerificationError((
        Diagnostic("error", "leak", "msg", tensor="X:a"),))
    assert isinstance(err, AssertionError)
    assert err.diagnostics[0].check == "leak"
    assert "[error:leak]" in str(err)


# ---------------------------------------------------------------------------
# Backend admission + runtime sanitizer
# ---------------------------------------------------------------------------

def _exec_inputs(cp):
    import jax
    import jax.numpy as jnp
    g = cp.graph
    params = cp.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (cp.batch,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(cp.batch) % 10, 10)
    return params, x, y


def test_backend_refuses_a_corrupted_schedule():
    cp = mutate_schedule.reference_plan()
    params, x, y = _exec_inputs(cp)
    cp.lowered = mutate_schedule.forge(cp, "drop_prefetch")
    assert not is_verified(cp.lowered)
    with pytest.raises(ScheduleVerificationError,
                       match="use_before_resident"):
        cp.loss_and_grads(params, x, y)


def test_backend_verifies_on_admission_when_compile_skipped_it():
    cp = compile_plan(
        ZOO["lenet5"](),
        MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                         min_idle_phases=3, min_bytes=1 << 12,
                         cooptimize=False, verify="off"),
        batch=8)
    assert cp.verify_report is None
    assert not is_verified(cp.lowered)
    params, x, y = _exec_inputs(cp)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.replayed_ops == cp.lowered.ops
    assert is_verified(cp.lowered)   # admission check ran and marked it


def test_sanitizer_cross_checks_every_replayed_op():
    import numpy as np
    from repro.core.exec.backends import SimulatedBackend
    from repro.core.exec.layers import reference_loss_and_grads
    cp = mutate_schedule.reference_plan()
    params, x, y = _exec_inputs(cp)
    loss, grads, stats = cp.loss_and_grads(
        params, x, y, executor=SimulatedBackend(sanitize=True))
    assert stats.sanitizer_checks == len(cp.lowered.ops)
    loss_r, grads_r = reference_loss_and_grads(cp.graph, params, x, y)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss_r),
                               rtol=1e-5, atol=1e-6)


def test_sanitizer_off_by_default():
    cp = mutate_schedule.reference_plan()
    params, x, y = _exec_inputs(cp)
    _, _, stats = cp.loss_and_grads(params, x, y)
    assert stats.sanitizer_checks == 0


# ---------------------------------------------------------------------------
# Zoo-wide clean sweep (the CI gate runs the full planner cross-product)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_every_zoo_model_compiles_with_zero_diagnostics(name):
    cp = compile_plan(ZOO[name](),
                      MemoryPlanConfig(min_idle_phases=3,
                                       min_bytes=1 << 12,
                                       cooptimize=False), batch=4)
    assert cp.verify_report is not None
    assert cp.verify_report.ok
    assert cp.verify_report.diagnostics == ()


@pytest.mark.parametrize("planner", ["sorting", "bestfit", "segregated",
                                     "buddy"])
@pytest.mark.parametrize("host_planner", ["sorting", "segregated"])
def test_planner_cross_product_verifies_clean_on_lenet5(planner,
                                                        host_planner):
    cp = compile_plan(
        ZOO["lenet5"](),
        MemoryPlanConfig(planner=planner, host_planner=host_planner,
                         min_idle_phases=3, min_bytes=1 << 12,
                         cooptimize=False), batch=4)
    assert cp.verify_report.ok


def test_verify_report_summary_shape():
    report = VerifyReport(diagnostics=(), checks_run=("heap",),
                          ops_scanned=3, placements_scanned=2,
                          wall_time_s=0.01, check_seconds={"heap": 0.01})
    s = report.summary()
    assert s == {"ok": True, "errors": 0, "warnings": 0,
                 "checks_run": ["heap"], "ops_scanned": 3,
                 "placements_scanned": 2, "wall_time_s": 0.01,
                 "check_wall_time_s": {"heap": 0.01}}


def test_warnings_do_not_fail_a_report():
    report = VerifyReport(
        diagnostics=(Diagnostic("warning", "budget", "close to peak"),),
        checks_run=("budget",), ops_scanned=1, placements_scanned=0,
        wall_time_s=0.0)
    assert report.ok
    assert len(report.warnings()) == 1
    report.raise_if_errors()   # no raise
    assert report.summary()["warnings"] == 1
