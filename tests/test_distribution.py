"""Distribution tests that need multiple devices: run in subprocesses with
``--xla_force_host_platform_device_count`` so the main test process keeps
its single-device view (per the dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path


ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, n_devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count"
               f"={n_devices}").strip()
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pipeline_parallel_matches_sequential():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.train.pipeline import (pipeline_apply, split_microbatches,
                                      bubble_fraction)
    mesh = jax.make_mesh((4,), ("stage",))
    S, M, B, D = 4, 8, 16, 32
    rng = jax.random.PRNGKey(0)
    ws = jax.random.normal(rng, (S, D, D)) * 0.3
    params = {"w": ws}

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    x = jax.random.normal(jax.random.fold_in(rng, 1), (M * B, D))
    xs = split_microbatches(x, M)
    with mesh:
        out = pipeline_apply(stage_fn, params, xs, mesh=mesh, axis="stage")
    out = np.asarray(out.reshape(M * B, D))

    ref = x
    for i in range(S):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(out, np.asarray(ref), rtol=2e-5, atol=2e-5)
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
    print("pipeline fwd OK")
    """)


def test_pipeline_parallel_gradients():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.train.pipeline import pipeline_loss, split_microbatches
    mesh = jax.make_mesh((4,), ("stage",))
    S, M, B, D = 4, 4, 8, 16
    rng = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(rng, (S, D, D)) * 0.3}

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    def loss_fn(y, t):
        return jnp.mean((y - t) ** 2)

    x = jax.random.normal(jax.random.fold_in(rng, 1), (M * B, D))
    t = jax.random.normal(jax.random.fold_in(rng, 2), (M * B, D))
    xs, ts = split_microbatches(x, M), split_microbatches(t, M)

    with mesh:
        gp = jax.grad(lambda p: pipeline_loss(
            stage_fn, loss_fn, p, xs, ts, mesh=mesh, axis="stage"))(params)

    def seq_loss(p):
        y = x
        for i in range(S):
            y = jnp.tanh(y @ p["w"][i])
        return jnp.mean(jax.vmap(loss_fn)(
            y.reshape(M, B, D), t.reshape(M, B, D)))

    gs = jax.grad(seq_loss)(params)
    np.testing.assert_allclose(np.asarray(gp["w"]), np.asarray(gs["w"]),
                               rtol=1e-4, atol=1e-5)
    print("pipeline grad OK")
    """)


def test_train_step_lowers_on_small_mesh():
    """Reduced arch through the real StepBundle machinery on a 4x2 mesh."""
    _run("""
    import jax
    from repro.configs import ARCHS, SHAPES
    from repro.configs.base import ShapeConfig
    from repro.models.model import build_model, reduce_config
    from repro.optim import make_optimizer
    from repro.train.step import build_step, lower_step
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = reduce_config(ARCHS["llama3.2-3b"], d_model=64, n_heads=4,
                        n_kv_heads=2, vocab=512)
    model = build_model(cfg)
    shape = ShapeConfig("t", 64, 8, "train")
    bundle = build_step(model, make_optimizer("adamw"), mesh, shape,
                        microbatches=2)
    compiled = lower_step(bundle).compile()
    assert compiled.cost_analysis() is not None
    print("train lower OK")
    """)


def test_train_step_executes_on_small_mesh():
    """Actually run two sharded train steps and check loss decreases."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.models.model import build_model, reduce_config
    from repro.optim import make_optimizer
    from repro.train.step import make_train_step
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = reduce_config(ARCHS["granite-moe-1b-a400m"], d_model=64,
                        n_heads=4, n_kv_heads=2, vocab=512)
    model = build_model(cfg)
    shape = ShapeConfig("t", 32, 8, "train")
    bundle = make_train_step(model, make_optimizer("adamw", lr=3e-3), mesh,
                             shape)
    step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                   out_shardings=bundle.out_shardings,
                   donate_argnums=bundle.donate_argnums)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = make_optimizer("adamw", lr=3e-3).init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)}
    with mesh:
        losses = []
        for _ in range(8):
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    print("train exec OK", losses[0], "->", losses[-1])
    """)


def test_decode_step_lowers_on_small_mesh():
    _run("""
    import jax
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.models.model import build_model, reduce_config
    from repro.train.step import build_step, lower_step
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for arch in ("zamba2-7b", "xlstm-1.3b", "phi4-mini-3.8b"):
        cfg = reduce_config(ARCHS[arch], d_model=64, vocab=512)
        model = build_model(cfg)
        shape = ShapeConfig("d", 128, 8, "decode")
        bundle = build_step(model, None, mesh, shape)
        lower_step(bundle).compile()
        print(arch, "decode lower OK")
    """)


def test_compressed_pod_allreduce():
    """EF-int8 cross-pod gradient reduction inside shard_map."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import compressed_psum_pod, init_residual
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    g_global = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 32))
    grads = {"w": g_global}
    res = {"w": jnp.zeros((64, 32))}

    @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P()), out_specs=P("pod"),
             check_rep=False)
    def reduce(g, r):
        local = {"w": g[0]}
        mean, new_res = compressed_psum_pod(local, {"w": r}, axis_name="pod")
        return mean["w"][None]

    out = reduce(g_global, res["w"])
    true_mean = np.asarray(g_global.mean(axis=0))
    got = np.asarray(out[0])
    scale = np.abs(true_mean).max()
    assert np.abs(got - true_mean).max() < scale * 0.05, "int8 mean too far"
    print("compressed pod all-reduce OK")
    """, n_devices=8)
