"""Launch-layer analysis tests: HLO collective parsing, roofline math,
probe extrapolation consistency (subprocess with multi-device host)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.launch.hlo_analysis import analyze_collectives, _shape_bytes

ROOT = Path(__file__).resolve().parents[1]

FAKE_HLO = """
HloModule test

ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %p1 = f32[16,16]{1,0} parameter(1)
  %ag = bf16[64,128]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[16,16]{1,0} all-reduce(%p1), to_apply=%add
  %rs = f32[2,16]{1,0} reduce-scatter(%p1), dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = (bf16[64,128]{1,0}) tuple(%ag)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[16,16]") == 16 * 16 * 4
    assert _shape_bytes("(bf16[2,2], f32[3])") == 8 + 12
    assert _shape_bytes("pred[]") == 1


def test_analyze_collectives_counts_and_bytes():
    out = analyze_collectives(FAKE_HLO)
    per = out["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-gather"]["operand_bytes"] == 8 * 128 * 2
    assert per["all-gather"]["result_bytes"] == 64 * 128 * 2
    assert per["all-reduce"]["count"] == 1
    assert per["all-reduce"]["operand_bytes"] == 16 * 16 * 4
    assert per["reduce-scatter"]["count"] == 1
    assert per["collective-permute"]["count"] == 1
    assert out["collective_bytes"] > 0


def test_roofline_cell_math():
    from repro.launch.roofline import analyze_cell
    from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
    rec = {
        "status": "ok", "arch": "llama3.2-3b", "shape": "train_4k",
        "mesh": "pod", "chips": 256,
        "probe": {"flops": 1e15, "bytes": 1e12, "collective_bytes": 1e11},
        "memory_analysis": {"peak_memory_in_bytes": 2 << 30},
    }
    a = analyze_cell(rec)
    assert abs(a["t_compute_s"] - 1e15 / PEAK_FLOPS_BF16) < 1e-9
    assert abs(a["t_memory_s"] - 1e12 / HBM_BW) < 1e-9
    assert abs(a["t_collective_s"] - 1e11 / ICI_BW) < 1e-9
    assert a["dominant"] == "compute"
    assert 0 < a["useful_compute_ratio"] < 1
    assert a["roofline_fraction"] <= 1.0


def test_roofline_skips_bad_cells():
    from repro.launch.roofline import analyze_cell
    assert analyze_cell({"status": "error"}) is None
    assert analyze_cell({"status": "ok", "probe": {"error": "x"},
                         "memory_analysis": {}}) is None


@pytest.mark.slow
def test_probe_linearity_small():
    """Unrolled probe FLOPs must grow linearly in depth: cost(3 layers)
    ~= fixed + 3*per_layer predicted from the 1/2-layer probes."""
    script = """
    import dataclasses, jax
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.launch.probe import _lower_and_cost, probe_config
    from repro.models.model import reduce_config
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = reduce_config(ARCHS["llama3.2-3b"], d_model=64, n_heads=4,
                        n_kv_heads=2, vocab=512)
    shape = ShapeConfig("t", 64, 4, "train")
    c1 = _lower_and_cost(probe_config(cfg, 1, 64), shape, mesh)
    c2 = _lower_and_cost(probe_config(cfg, 2, 64), shape, mesh)
    c3 = _lower_and_cost(probe_config(cfg, 3, 64), shape, mesh)
    per = c2["flops"] - c1["flops"]
    pred3 = c1["flops"] + 2 * per
    err = abs(c3["flops"] - pred3) / max(c3["flops"], 1)
    assert err < 0.05, (c1["flops"], c2["flops"], c3["flops"], err)
    print("probe linearity OK", err)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"


def test_dryrun_results_schema():
    """Whatever dry-run artifacts exist must carry the full schema."""
    results = ROOT / "results" / "dryrun"
    files = list(results.glob("*.json")) if results.exists() else []
    if not files:
        pytest.skip("no dry-run artifacts yet")
    for p in files:
        rec = json.loads(p.read_text())
        assert rec["status"] in ("ok", "skipped", "error"), p
        if rec["status"] == "ok":
            assert rec["chips"] in (256, 512)
            assert "cost_analysis" in rec and "collectives" in rec
            assert "memory_analysis" in rec
