"""Core algorithm tests: execution orders, memory planners, ideal memory.

Includes hypothesis property tests for the planner invariants:
  * no two lifetime-overlapping tensors share bytes (soundness)
  * planner peak >= ideal peak (lower bound)
  * planner peak <= worst-case/naive peak (usefulness)
"""

import pytest

try:  # optional dev dependency — the deterministic tests below always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.execution_order import compute_execution_order
from repro.core.graph import LayerGraph, LayerNode, compile_graph
from repro.core.ideal import PAPER_TABLE4_KIB, ideal_from_ordered, ideal_memory
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec
from repro.core.planner import (BestFitPlanner, SortingPlanner,
                                WorstCasePlanner, plan_memory)
from repro.core.zoo import ZOO


# ---------------------------------------------------------------------------
# Table 4 reproduction (the paper's ideal-memory numbers, batch 64)
# ---------------------------------------------------------------------------

EXACT_CASES = [
    "linear", "conv2d", "lstm", "model_a_linear", "model_a_conv2d",
    "model_b_linear", "model_c_linear", "model_c_conv2d", "model_d",
]


@pytest.mark.parametrize("name", EXACT_CASES)
def test_table4_ideal_memory_matches_paper(name):
    g = ZOO[name]()
    im = ideal_memory(g, 64)
    paper = PAPER_TABLE4_KIB[name]
    assert abs(im.total_kib / paper - 1.0) < 0.005, (
        f"{name}: ideal {im.total_kib:.1f} KiB vs paper {paper} KiB"
    )


def test_table4_model_b_conv2d_documented_residual():
    # The paper's Model B (Conv2D) number implies the activation output and
    # its derivative never coexist, which is impossible for a sigmoid whose
    # derivative reads the output; our number is the achievable minimum for
    # the stated shapes (documented in EXPERIMENTS.md §Table4).
    g = ZOO["model_b_conv2d"]()
    im = ideal_memory(g, 64)
    assert im.total_kib / PAPER_TABLE4_KIB["model_b_conv2d"] < 1.2


@pytest.mark.parametrize("name", list(PAPER_TABLE4_KIB))
def test_planner_peak_close_to_ideal(name):
    """Paper Fig. 9: NNTrainer's measured peak ~= ideal (ignorable overhead)."""
    g = ZOO[name]()
    ordered = compute_execution_order(g, 64)
    ideal = ideal_from_ordered(ordered)
    plan = plan_memory(ordered, "sorting")
    # alignment + fragmentation overhead must stay tiny
    assert plan.total_bytes <= ideal.total_bytes * 1.05 + 16384


# ---------------------------------------------------------------------------
# Execution-order semantics (Figure 4/5/6)
# ---------------------------------------------------------------------------

def _simple_graph(n_linear=3):
    layers = []
    prev = "__input__"
    for i in range(n_linear):
        layers.append(LayerNode(f"fc{i}", "linear", [prev],
                                {"in_features": 8, "out_features": 8,
                                 "bias": False}))
        prev = f"fc{i}"
    layers.append(LayerNode("loss", "loss_mse", [prev]))
    return compile_graph(LayerGraph(layers, (8,), (8,), "t"))


def test_eo_forward_ascending_backward_descending():
    g = _simple_graph(3)
    o = compute_execution_order(g, 4)
    fs = [o.layer_orders[f"fc{i}"][0] for i in range(3)]
    cgs = [o.layer_orders[f"fc{i}"][1] for i in range(3)]
    assert fs == sorted(fs)
    assert cgs == sorted(cgs, reverse=True)
    # CD follows CG immediately (Algorithm 1 line 6)
    for i in range(3):
        f, cg, cd = o.layer_orders[f"fc{i}"]
        assert cd == cg + 1


def test_saved_activation_freed_after_consumer_cg():
    """Fig. 4: X1's last use is L1's CG, not L0's."""
    g = _simple_graph(3)
    o = compute_execution_order(g, 4)
    x0 = o.tensors["X:fc0"]
    _, cg1, _ = o.layer_orders["fc1"]
    assert x0.max_eo == cg1


def test_weight_lifespan_spans_everything():
    g = _simple_graph(2)
    o = compute_execution_order(g, 4)
    w = o.tensors["W:fc0:w"]
    assert w.min_eo == 0 and w.max_eo == o.eo_max


def test_inplace_activation_merges():
    """Fig. 5: activation output is an MV view merged into its input."""
    layers = [
        LayerNode("fc0", "linear", ["__input__"],
                  {"in_features": 8, "out_features": 8, "bias": False,
                   "activation": "sigmoid"}),
        LayerNode("fc1", "linear", ["fc0"],
                  {"in_features": 8, "out_features": 4, "bias": False}),
        LayerNode("loss", "loss_mse", ["fc1"]),
    ]
    g = compile_graph(LayerGraph(layers, (8,), (4,), "t"))
    o = compute_execution_order(g, 4)
    assert o.tensors["X:fc0__act"].merged_into == "X:fc0"
    # derivative of the activation input is an in-place MV of its output deriv
    assert o.tensors["D:fc0"].merged_into is not None


def test_flatten_rv_merges_despite_overlap():
    """Fig. 6: RV merges even when intervals overlap."""
    g = ZOO["model_c_linear"]()
    o = compute_execution_order(g, 4)
    flat = [t for n, t in o.tensors.items() if "flat" in n and n.startswith("X:")]
    assert flat and all(t.merged_into is not None for t in flat)


def test_mv_never_merges_into_placeholder():
    g = ZOO["model_d"]()
    o = compute_execution_order(g, 4)
    # both activation branches read the (placeholder) input via multiout;
    # neither may overwrite external memory
    for n in ("X:act_a", "X:act_b"):
        t = o.tensors[n]
        assert t.create_mode == CreateMode.CREATE and t.merged_into is None


def test_unrolled_weights_are_extend_shared():
    g = ZOO["tacotron2_decoder"]()
    o = compute_execution_order(g, 4)
    owners = {n: t for n, t in o.tensors.items()
              if n.startswith("W:lstm0__t") and n.endswith(":wx")}
    merged = [t for t in owners.values() if t.merged_into is not None]
    assert len(merged) == len(owners) - 1  # all but the first copy share


def test_transfer_learning_prunes_backbone_derivatives():
    g = ZOO["resnet18_transfer"]()
    o = compute_execution_order(g, 4)
    # frozen backbone: no gradient tensors, no derivative tensors
    assert not any(n.startswith("G:r") for n in o.tensors)
    assert not any(n.startswith("D:r") for n in o.tensors)
    # classifier still trains
    assert any(n.startswith("G:fc") for n in o.tensors)


# ---------------------------------------------------------------------------
# Planner invariants (hypothesis property tests)
# ---------------------------------------------------------------------------

class _FakeOrdered:
    def __init__(self, tensors, eo_max):
        self.tensors = {t.name: t for t in tensors}
        self.merged = {}
        self.eo_max = eo_max
        self.layer_orders = {}

    def planned_tensors(self):
        return list(self.tensors.values())


if HAVE_HYPOTHESIS:
    @st.composite
    def random_tensor_set(draw):
        n = draw(st.integers(min_value=1, max_value=40))
        eo_max = draw(st.integers(min_value=2, max_value=60))
        tensors = []
        for i in range(n):
            a = draw(st.integers(min_value=0, max_value=eo_max))
            b = draw(st.integers(min_value=0, max_value=eo_max))
            lo, hi = min(a, b), max(a, b)
            nbytes = draw(st.integers(min_value=1, max_value=1 << 20))
            t = TensorSpec(name=f"t{i}", shape=(nbytes,), dtype="uint8",
                           lifespan=Lifespan.FORWARD,
                           create_mode=CreateMode.CREATE)
            t.exec_orders = (lo, hi)
            tensors.append(t)
        return tensors, eo_max

    @given(random_tensor_set())
    @settings(max_examples=80, deadline=None)
    def test_planner_soundness_and_bounds(data):
        tensors, eo_max = data
        ordered = _FakeOrdered(tensors, eo_max)

        naive = WorstCasePlanner().plan(_FakeOrdered(tensors, eo_max))
        ideal = ideal_from_ordered(ordered)

        for cls in (SortingPlanner, BestFitPlanner):
            plan = cls().plan(_FakeOrdered(
                [TensorSpec(t.name, t.shape, t.dtype, t.lifespan,
                            t.create_mode, exec_orders=t.exec_orders)
                 for t in tensors], eo_max))
            plan.validate()  # no overlapping live tensors
            assert plan.arena_bytes >= ideal.arena_bytes  # >= lower bound
            assert plan.arena_bytes <= naive.arena_bytes + 64 * len(tensors)

    @given(random_tensor_set())
    @settings(max_examples=40, deadline=None)
    def test_bestfit_never_worse_than_twice_ideal_on_random_sets(data):
        # classic interval-packing guarantee check (loose): best-fit stays
        # within a small constant of the lower bound on random workloads
        tensors, eo_max = data
        ideal = ideal_from_ordered(_FakeOrdered(tensors, eo_max))
        plan = BestFitPlanner().plan(_FakeOrdered(tensors, eo_max))
        assert plan.arena_bytes <= max(2 * ideal.arena_bytes,
                                       64 * len(tensors))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_planner_soundness_and_bounds():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_bestfit_never_worse_than_twice_ideal_on_random_sets():
        pass


def test_planner_deterministic():
    g = ZOO["resnet18"]()
    p1 = plan_memory(compute_execution_order(g, 8), "sorting")
    p2 = plan_memory(compute_execution_order(ZOO["resnet18"](), 8), "sorting")
    assert p1.arena_bytes == p2.arena_bytes
    assert {n: p.offset for n, p in p1.placements.items()} == \
           {n: p.offset for n, p in p2.placements.items()}


def test_bestfit_beats_or_ties_sorting_on_models():
    """Beyond-paper claim: best-fit fragmentation <= Algorithm 2's."""
    for name in ("model_b_conv2d", "resnet18", "vgg16", "lenet5"):
        o1 = compute_execution_order(ZOO[name](), 16)
        o2 = compute_execution_order(ZOO[name](), 16)
        s = SortingPlanner().plan(o1)
        b = BestFitPlanner().plan(o2)
        assert b.arena_bytes <= s.arena_bytes


def test_peak_known_before_execution():
    """§4.2: peak memory is computable before any allocation."""
    g = ZOO["vgg16"]()
    ordered = compute_execution_order(g, 32)
    plan = plan_memory(ordered, "bestfit")
    assert plan.arena_bytes > 0
    assert plan.total_bytes == plan.arena_bytes + plan.external_bytes
