"""Joint keep/recompute/offload planner (model-config path).

Covers: 3-way pricing under the hardware cost model, optimality against the
two single-knob plans (pure remat, offload-everything) over the arch
registry and a budget sweep, honest accounting (recompute FLOPs, DMA bytes,
budget-missing names preserved), the deprecated ``offload_dropped`` alias,
the fallback-save lowering warning, and the co-optimisation scan's
fixed-point invariant on every zoo model.
"""

import dataclasses

import pytest

from repro.configs import ARCHS
from repro.core.offload import make_schedule, offload_lowering
from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planner import plan_memory_swapped
from repro.core.remat_policy import (plan_checkpoint_policy,
                                     plan_joint_policy, plan_step_time_s,
                                     transformer_intermediates)
from repro.core.zoo import ZOO

# A hardware point where the eviction lanes genuinely compete for the big
# dense archs (recompute density ~d_model prefers FLOPs, ~d_ff prefers DMA).
HW = {"dma_gbps": 80.0, "device_tflops": 200.0}


def _intermediates(cfg, batch_tokens=2048):
    return transformer_intermediates(
        batch_tokens=batch_tokens, d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff if cfg.is_moe else cfg.d_ff,
        n_q_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, moe_experts_per_token=cfg.top_k)


def _cost(plan, inter):
    return plan_step_time_s(plan, inter, **HW)


# ---------------------------------------------------------------------------
# Optimality: the joint plan never loses to either single-knob plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("frac", (0.0, 0.25, 0.5, 0.75))
def test_joint_plan_never_worse_than_single_knob_plans(arch, frac):
    inter = _intermediates(ARCHS[arch])
    total = sum(i.bytes_per_layer for i in inter)
    budget = int(total * frac)
    joint = plan_joint_policy(inter, budget, offload=True, **HW)
    pure = plan_joint_policy(inter, budget, offload=False)
    with pytest.warns(DeprecationWarning):
        offall = plan_checkpoint_policy(inter, budget, offload_dropped=True)
    # estimated step-time cost, all three priced under the SAME honest model
    assert _cost(joint, inter) <= _cost(pure, inter) + 1e-15
    assert _cost(joint, inter) <= _cost(offall, inter) + 1e-15
    # keep-bytes never exceed the budget
    assert joint.saved_bytes_per_layer <= budget
    # the decision partition is total: budget-missing names are preserved,
    # split between the two eviction lanes, never erased
    assert (set(joint.saved) | set(joint.dropped) | set(joint.offloaded)
            == {i.name for i in inter})
    assert not set(joint.dropped) & set(joint.offloaded)
    assert not set(joint.saved) & (set(joint.dropped) | set(joint.offloaded))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_joint_plan_honest_accounting(arch):
    inter = _intermediates(ARCHS[arch])
    total = sum(i.bytes_per_layer for i in inter)
    joint = plan_joint_policy(inter, total // 4, offload=True, **HW)
    by = {i.name: i for i in inter}
    assert joint.recompute_flops_per_layer == sum(
        by[n].recompute_flops for n in joint.dropped)
    assert joint.offload_dma_bytes_per_layer == sum(
        2 * by[n].bytes_per_layer for n in joint.offloaded)
    # the plan's own estimate equals the honest re-pricing (same model)
    assert joint.est_step_time_s_per_layer == pytest.approx(
        _cost(joint, inter))
    decisions = joint.decisions()
    assert set(decisions) == {i.name for i in inter}


# ---------------------------------------------------------------------------
# Acceptance: a mixed decision set strictly beats both legacy modes
# ---------------------------------------------------------------------------

def test_joint_plan_mixed_decisions_beat_both_legacy_modes():
    cfg = ARCHS["llama3.2-3b"]
    bt = 2048
    inter = _intermediates(cfg, bt)
    budget = 1 << 20   # tight: every intermediate must be evicted
    joint_cp = compile_plan(cfg, MemoryPlanConfig(
        remat=True, remat_budget_bytes=budget, offload=True, **HW),
        batch_tokens=bt)
    rp = joint_cp.remat_plan
    # genuinely mixed: some intermediates recomputed AND some offloaded
    assert rp.dropped and rp.offloaded
    # the DMA price is visible on the compiled model plan, not zeroed
    assert joint_cp.report()["dma_bytes"] > 0
    assert joint_cp.dma_bytes == \
        rp.offload_dma_bytes_per_layer * cfg.n_layers
    pure_cp = compile_plan(cfg, MemoryPlanConfig(
        remat=True, remat_budget_bytes=budget, offload=False),
        batch_tokens=bt)
    with pytest.warns(DeprecationWarning):
        offall_cp = compile_plan(cfg, MemoryPlanConfig(
            remat=True, remat_budget_bytes=budget, offload_dropped=True),
            batch_tokens=bt)
    cj = _cost(rp, inter)
    assert cj < _cost(pure_cp.remat_plan, inter)      # strictly below remat
    assert cj < _cost(offall_cp.remat_plan, inter)    # and offload-all


# ---------------------------------------------------------------------------
# Deprecated aliases keep their decision sets, with honest accounting
# ---------------------------------------------------------------------------

def test_free_dma_alias_offloads_everything_with_honest_dma():
    inter = _intermediates(ARCHS["llama3.2-3b"])
    with pytest.warns(DeprecationWarning):
        plan = plan_checkpoint_policy(inter, 0, offload_dropped=True)
    assert set(plan.offloaded) == {i.name for i in inter}
    assert plan.dropped == () and plan.recompute_flops_per_layer == 0.0
    assert plan.offload_dma_bytes_per_layer == \
        2 * sum(i.bytes_per_layer for i in inter)
    # DMA was priced as free when planning — exactly why the alias is
    # deprecated; plan_step_time_s re-prices it honestly
    assert plan.est_step_time_s_per_layer == 0.0
    assert _cost(plan, inter) > 0.0


def test_pure_remat_wrapper_is_joint_planner_with_offload_lane_off():
    inter = _intermediates(ARCHS["phi4-mini-3.8b"])
    total = sum(i.bytes_per_layer for i in inter)
    assert plan_checkpoint_policy(inter, total // 2) == \
        plan_joint_policy(inter, total // 2, offload=False)
    assert plan_checkpoint_policy(inter, None) == \
        plan_joint_policy(inter, None, offload=False)


def test_zero_bandwidth_disables_offload_lane():
    # dma_gbps=0 must mean "no DMA engine" (infinite price), not crash
    inter = _intermediates(ARCHS["llama3.2-3b"])
    plan = plan_joint_policy(inter, 0, offload=True, dma_gbps=0.0,
                             device_tflops=200.0)
    assert not plan.offloaded
    assert set(plan.dropped) == {i.name for i in inter}


def test_free_dma_alias_nonzero_budget_keeps_historical_greedy_fill():
    # the alias must reproduce the old greedy flops-per-byte keep set, not
    # the byte-maximising knapsack tiebreak (every value is zero under
    # free DMA, so the knapsack is degenerate there)
    from repro.core.remat_policy import Intermediate
    inter = [Intermediate("a", 6, 100.0), Intermediate("b", 5, 10.0),
             Intermediate("c", 5, 9.0)]
    with pytest.warns(DeprecationWarning):
        plan = plan_checkpoint_policy(inter, 10, offload_dropped=True)
    assert plan.saved == ("a",)            # densest first, then b/c don't fit
    assert set(plan.offloaded) == {"b", "c"}


def test_budgetless_offload_lane_warns_instead_of_silent_noop():
    # with no budget pressure the optimum keeps everything; the facade must
    # say so rather than let offload=True silently do nothing
    cfg = dataclasses.replace(ARCHS["llama3.2-3b"], offload=True)
    with pytest.warns(UserWarning, match="nothing will be offloaded"):
        cp = compile_plan(cfg, batch_tokens=2048)
    assert not cp.remat_plan.offloaded
    assert set(cp.remat_plan.saved) == \
        {"qkv", "attn_out", "mlp_hidden", "mlp_out"}


def test_model_config_hardware_knobs_flow_through_facade():
    cfg = dataclasses.replace(
        ARCHS["llama3.2-3b"], offload=True, remat_budget_bytes=1 << 20,
        dma_gbps=80.0, device_tflops=200.0)
    cp = compile_plan(cfg, batch_tokens=2048)
    assert cp.remat_plan.offloaded     # cfg knobs alone enable the lane
    # MemoryPlanConfig overrides cfg: near-zero bandwidth prices every
    # eviction down the recompute lane
    slow = compile_plan(cfg, MemoryPlanConfig(dma_gbps=1e-6),
                        batch_tokens=2048)
    assert not slow.remat_plan.offloaded and slow.remat_plan.dropped
    assert slow.report()["recompute_flops_per_layer"] > 0


# ---------------------------------------------------------------------------
# Offload lowering degradation is loud and reported
# ---------------------------------------------------------------------------

def test_offload_policy_fallback_warns_and_is_reported(monkeypatch):
    import jax
    from repro.core.offload import offload_policy
    monkeypatch.delattr(jax.checkpoint_policies,
                        "save_and_offload_only_these_names")
    assert offload_lowering() == "fallback_save"
    with pytest.warns(RuntimeWarning, match="fallback_save"):
        assert offload_policy(["mlp_hidden"], saved=["attn_out"]) is not None
    cp = compile_plan(ARCHS["llama3.2-3b"], MemoryPlanConfig(
        remat=True, remat_budget_bytes=1 << 20, offload=True, **HW),
        batch_tokens=2048)
    assert cp.report()["offload_lowering"] == "fallback_save"


def test_offload_lowering_native_on_this_jax():
    import jax
    if not hasattr(jax.checkpoint_policies,
                   "save_and_offload_only_these_names"):
        pytest.skip("installed JAX lacks the offload policy")
    assert offload_lowering() == "native"
    cp = compile_plan(ARCHS["llama3.2-3b"], MemoryPlanConfig(
        remat=True, remat_budget_bytes=1 << 20, offload=True, **HW),
        batch_tokens=2048)
    assert cp.report()["offload_lowering"] == "native"
    # keep-everything plans offload nothing, so no lowering key is reported
    full = compile_plan(ARCHS["llama3.2-3b"], MemoryPlanConfig(remat=True),
                        batch_tokens=2048)
    assert "offload_lowering" not in full.report()


# ---------------------------------------------------------------------------
# Co-optimisation scan fix: the fixed point still holds on every zoo model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_coopt_fixed_point_invariant_on_every_zoo_model(name):
    cp = compile_plan(
        ZOO[name](), MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12),
        batch=8)
    # fixed point: no remaining data-moving swap is droppable — removing
    # any one of them must raise the packed peak.  In-place decisions are
    # exempt: they move no data (no host slot, no DMA), so the scan keeps
    # them for the planner freedom they preserve.
    for d in cp.schedule.decisions:
        if d.inplace:
            continue
        rest = tuple(o for o in cp.schedule.decisions if o.name != d.name)
        trial = plan_memory_swapped(cp.ordered, make_schedule(rest),
                                    planner=cp.config.planner)
        assert trial.arena_bytes > cp.peak_bytes, d.name
    # the exempt decisions really are free: zero bytes in every aggregate
    inplace = [d for d in cp.schedule.decisions if d.inplace]
    assert cp.schedule.dma_bytes == 2 * sum(
        d.nbytes for d in cp.schedule.decisions if not d.inplace)
    for d in inplace:
        assert d.name + "@host" not in cp.plan.host.placements
