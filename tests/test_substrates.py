"""Substrate tests: data pipeline, optimizers, compression, checkpointing,
fault tolerance, offload/remat planning."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dependency — the deterministic tests below always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import (BatchQueue, DataState, host_batch_slice,
                                 synthetic_lm_producer)
from repro.optim import make_optimizer
from repro.optim.compression import (compress_gradients, decompress_gradients,
                                     error_feedback_update, init_residual)
from repro.runtime.fault import (Heartbeat, RestartPolicy, StepWatchdog,
                                 elastic_new_mesh)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_producer_deterministic():
    p = synthetic_lm_producer(vocab=100, seq_len=16)
    a = p(0, 7, None)
    b = p(0, 7, None)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p(0, 8, None)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_batch_queue_shapes_and_state():
    p = synthetic_lm_producer(vocab=100, seq_len=8)
    q = BatchQueue(p, batch=4, state=DataState())
    batch, state = q.get()
    assert batch["tokens"].shape == (4, 8)
    assert state.index == 4
    batch2, state2 = q.get()
    assert state2.index == 8
    # stream continues without repeats
    assert not np.array_equal(batch["tokens"], batch2["tokens"])
    q.close()


def test_batch_queue_resume_reproduces_stream():
    p = synthetic_lm_producer(vocab=100, seq_len=8)
    q1 = BatchQueue(p, batch=4, state=DataState())
    b1, s1 = q1.get()
    b2, _ = q1.get()
    q1.close()
    # restart from the saved state: must reproduce the SECOND batch
    q2 = BatchQueue(p, batch=4, state=s1)
    b2r, _ = q2.get()
    q2.close()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])


def test_host_batch_slice():
    assert host_batch_slice(256, 0, 16) == (0, 16)
    assert host_batch_slice(256, 15, 16) == (240, 16)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    params = {"a": {"w": jnp.ones((8, 8)) * 2.0}, "b": jnp.ones((8,))}
    target = {"a": {"w": jnp.zeros((8, 8))}, "b": jnp.zeros((8,))}

    def loss_fn(p):
        return sum(jnp.sum((x - t) ** 2) for x, t in zip(
            jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(target)))
    return params, loss_fn


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(state_dtype):
    params, loss_fn = _quad_problem()
    opt = make_optimizer("adamw", lr=0.05, weight_decay=0.0,
                         state_dtype=state_dtype)
    state = opt.init(params)
    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)) < l0 * 0.05


def test_int8_adamw_tracks_fp32():
    params, loss_fn = _quad_problem()
    o32 = make_optimizer("adamw", lr=0.05, weight_decay=0.0)
    o8 = make_optimizer("adamw", lr=0.05, weight_decay=0.0,
                        state_dtype="int8")
    p32, s32 = params, o32.init(params)
    p8, s8 = params, o8.init(params)
    for _ in range(20):
        g32 = jax.grad(loss_fn)(p32)
        g8 = jax.grad(loss_fn)(p8)
        p32, s32 = o32.update(g32, s32, p32)
        p8, s8 = o8.update(g8, s8, p8)
    for a, b in zip(jax.tree_util.tree_leaves(p32),
                    jax.tree_util.tree_leaves(p8)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.15, atol=0.05)


def test_sgd_momentum_converges():
    params, loss_fn = _quad_problem()
    opt = make_optimizer("sgd", lr=0.05)
    state = opt.init(params)
    l0 = float(loss_fn(params))
    for _ in range(50):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)) < l0 * 0.05


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_accuracy():
    rng = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(rng, (64, 32)),
             "b": jax.random.normal(jax.random.fold_in(rng, 1), (7,))}
    c = compress_gradients(grads)
    d = decompress_gradients(c, grads)
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(d)):
        err = np.abs(np.asarray(a) - np.asarray(b)).max()
        scale = np.abs(np.asarray(a)).max()
        assert err <= scale / 127.0 * 1.01


def test_error_feedback_is_unbiased_over_time():
    """Accumulated (decompressed + residual) equals the true gradient sum."""
    rng = np.random.default_rng(0)
    grads_seq = [
        {"w": jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))}
        for _ in range(10)
    ]
    residual = init_residual(grads_seq[0])
    sent_total = jnp.zeros((32, 16))
    for g in grads_seq:
        c, residual = error_feedback_update(g, residual)
        sent_total = sent_total + decompress_gradients(c, g)["w"]
    true_total = sum(g["w"] for g in grads_seq)
    # sent + remaining residual == true sum (error feedback invariant)
    np.testing.assert_allclose(np.asarray(sent_total + residual["w"]),
                               np.asarray(true_total), rtol=1e-4, atol=1e-4)


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=1, max_value=2000))
    @settings(max_examples=20, deadline=None)
    def test_compression_handles_any_size(n):
        g = {"x": jnp.arange(n, dtype=jnp.float32) / max(n, 1)}
        d = decompress_gradients(compress_gradients(g), g)
        assert d["x"].shape == (n,)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_compression_handles_any_size():
        pass


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
            "opt": {"m": jnp.ones((4, 6)) * 0.5,
                    "count": jnp.array(3, jnp.int32)}}


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = _tree()
        mgr.save(10, tree, {"epoch": 1, "index": 42}, blocking=True)
        assert mgr.latest_step() == 10
        restored, ds = mgr.restore(10, jax.eval_shape(lambda: _tree()))
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ds == {"epoch": 1, "index": 42}


def test_checkpoint_gc_keeps_last_k():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(), blocking=True)
        assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(5, _tree(), blocking=True)
        # a stale tmp dir from a crashed save must be ignored
        os.makedirs(os.path.join(d, "step_6.tmp"))
        assert mgr.latest_step() == 5


def test_checkpoint_elastic_reshard():
    """Restore onto a different sharding layout (mesh change)."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = _tree()
        mgr.save(1, tree, blocking=True)
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        shardings = {"w": NamedSharding(mesh, P("data")),
                     "opt": {"m": NamedSharding(mesh, P()),
                             "count": NamedSharding(mesh, P())}}
        restored, _ = mgr.restore(1, jax.eval_shape(lambda: _tree()),
                                  shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_dead_detection():
    with tempfile.TemporaryDirectory() as d:
        hb0 = Heartbeat(d, 0)
        hb0.beat(step=5)
        now = time.time()
        assert Heartbeat.dead_hosts(d, 2, timeout=60, now=now) == [1]
        assert Heartbeat.dead_hosts(d, 2, timeout=60, now=now + 120) == [0, 1]


def test_watchdog_escalates():
    wd = StepWatchdog(window=16, factor=2.0, exclude_after=2,
                      restart_after=4)
    for i in range(8):
        assert wd.record(i, 1.0, slowest_host=3) is None
    actions = []
    for i in range(8, 13):
        ev = wd.record(i, 5.0, slowest_host=3)
        if ev:
            actions.append(ev.action)
    assert actions[0] == "log"
    assert "exclude" in actions
    assert actions[-1] == "restart"


def test_restart_policy_budget():
    rp = RestartPolicy(max_restarts=3, base_backoff_s=1.0)
    waits = [rp.next_backoff() for _ in range(4)]
    assert waits[:3] == [1.0, 2.0, 4.0]
    assert waits[3] is None


def test_elastic_new_mesh():
    (data, model), plan = elastic_new_mesh(32, chips_per_host=8)
    assert data * model <= 32 * 8
    assert model == 16
    (data2, _), plan2 = elastic_new_mesh(30, chips_per_host=8)
    assert data2 <= 15
    assert plan2["microbatch_scale"] >= 1


# ---------------------------------------------------------------------------
# Remat / offload planning (core integration)
# ---------------------------------------------------------------------------

def test_remat_plan_budget_monotone():
    from repro.core.remat_policy import (plan_checkpoint_policy,
                                         transformer_intermediates)
    inter = transformer_intermediates(
        batch_tokens=4096, d_model=1024, d_ff=4096, n_q_heads=16,
        n_kv_heads=8, head_dim=64)
    full = plan_checkpoint_policy(inter, None)
    assert not full.dropped
    none = plan_checkpoint_policy(inter, 0)
    assert not none.saved
    total = sum(i.bytes_per_layer for i in inter)
    half = plan_checkpoint_policy(inter, total // 2)
    assert half.saved_bytes_per_layer <= total // 2
    assert 0 < len(half.saved) < len(inter)
    # kept intermediates have the highest recompute-cost density
    kept = {i.name for i in inter if i.name in half.saved}
    dens = {i.name: i.recompute_flops / i.bytes_per_layer for i in inter}
    for k in kept:
        for d in half.dropped:
            if dens[d] > dens[k]:
                # only legal if the denser one did not fit
                nd = next(i for i in inter if i.name == d)
                assert (half.saved_bytes_per_layer + nd.bytes_per_layer
                        > total // 2)


def test_offload_schedule_from_eos():
    from repro.core.execution_order import compute_execution_order
    from repro.core.offload import plan_offload
    from repro.core.zoo import vgg16
    ordered = compute_execution_order(vgg16(), 32)
    sched = plan_offload(ordered, min_idle_phases=6, min_bytes=1 << 18)
    assert sched.decisions, "deep conv stack must yield offload candidates"
    for d in sched.decisions:
        assert d.read_eo - d.write_eo >= 6
        assert d.nbytes >= 1 << 18
        assert d.write_eo <= d.prefetch_at_eo < d.read_eo
    assert sched.dma_bytes == 2 * sched.hbm_bytes_saved
