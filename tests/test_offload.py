"""Proactive-swap subsystem tests: EO-driven offload scheduling, swap-aware
arena planning (residency-interval splitting + host pool), and the
phase-by-phase swap executor (gradients vs jax.grad, HBM high-water bound).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.execution_order import compute_execution_order
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec
from repro.core.offload import OffloadSchedule, offload_policy, plan_offload
from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planned_exec import (init_params, reference_loss_and_grads,
                                     swap_planned_loss_and_grads)
from repro.core.planner import plan_memory, plan_memory_swapped
from repro.core.zoo import ZOO


class _FakeOrdered:
    def __init__(self, tensors, eo_max=100):
        self.tensors = {t.name: t for t in tensors}
        self.merged = {}
        self.eo_max = eo_max
        self.layer_orders = {}

    def planned_tensors(self):
        return [t for t in self.tensors.values()
                if t.create_mode == CreateMode.CREATE]


def _x(name, nbytes, orders):
    t = TensorSpec(name=f"X:{name}", shape=(nbytes,), dtype="uint8",
                   lifespan=Lifespan.FORWARD_GRAD,
                   create_mode=CreateMode.CREATE)
    t.exec_orders = tuple(sorted(orders))
    return t


# ---------------------------------------------------------------------------
# plan_offload: candidate filtering, gap analysis, budget, inflight peak
# ---------------------------------------------------------------------------

def test_candidate_filtering_idle_and_bytes():
    ordered = _FakeOrdered([
        _x("big_long", 1 << 20, (0, 50)),     # qualifies
        _x("big_short", 1 << 20, (0, 3)),     # idle too short
        _x("small_long", 128, (0, 50)),       # too small
    ])
    sched = plan_offload(ordered, min_idle_phases=4, min_bytes=1 << 10)
    assert sched.names() == ("X:big_long",)
    assert sched.hbm_bytes_saved == 1 << 20
    assert sched.dma_bytes == 2 * (1 << 20)


def test_non_activation_tensors_never_offloaded():
    w = TensorSpec(name="W:fc0:w", shape=(1 << 20,), dtype="uint8",
                   lifespan=Lifespan.MAX, create_mode=CreateMode.CREATE)
    w.exec_orders = (0, 100)
    sched = plan_offload(_FakeOrdered([w]), min_idle_phases=1, min_bytes=1)
    assert not sched.decisions


def test_idle_window_is_largest_gap_not_minmax():
    """A consumer-forward read right after production must not be raced:
    the idle window opens after the LAST pre-gap access."""
    ordered = _FakeOrdered([_x("a", 1 << 20, (0, 1, 2, 40, 44))])
    sched = plan_offload(ordered, min_idle_phases=4, min_bytes=1)
    (d,) = sched.decisions
    assert (d.write_eo, d.read_eo) == (2, 40)
    assert d.idle_phases == 38
    assert d.swap_out_eo == 3
    assert d.write_eo < d.prefetch_at_eo < d.read_eo
    assert d.vacates


def test_budget_early_exit_takes_best_candidates_first():
    ordered = _FakeOrdered([
        _x("a", 4 << 20, (0, 50)),    # byte-phases: 4M * 50  (best)
        _x("b", 2 << 20, (1, 50)),    # 2M * 49
        _x("c", 1 << 20, (2, 50)),    # 1M * 48
    ])
    sched = plan_offload(ordered, min_idle_phases=4, min_bytes=1,
                         hbm_budget_bytes=5 << 20)
    # a (4M) alone misses the budget; a+b (6M) meets it; c never chosen
    assert sched.names() == ("X:a", "X:b")
    assert sched.hbm_bytes_saved == 6 << 20


def test_peak_inflight_prefetch_accounting():
    # two prefetch windows overlap at EO 46..48; the third is disjoint and
    # smaller, so the peak is the overlapping pair's sum
    ordered = _FakeOrdered([
        _x("a", 1 << 20, (0, 48)),    # prefetch at 46
        _x("b", 2 << 20, (1, 48)),    # prefetch at 46
        _x("c", 1 << 19, (2, 20)),    # prefetch at 18, alone in flight
    ])
    sched = plan_offload(ordered, min_idle_phases=4, min_bytes=1,
                         prefetch_margin=2)
    assert sched.peak_inflight_prefetch == 3 << 20


def test_offload_policy_constructs():
    p = offload_policy(["mlp_hidden"], saved=["attn_out"])
    assert p is not None


# ---------------------------------------------------------------------------
# Swap-aware plan: residency splitting, host pool, validation
# ---------------------------------------------------------------------------

def test_swap_plan_vacates_and_reuses_bytes():
    """The vacated window must be reusable: a tensor living only inside
    another's idle window fits without growing the arena."""
    big = _x("big", 1 << 20, (0, 50))
    mid = _x("mid", 1 << 20, (10, 20))   # entirely inside big's idle window
    ordered = _FakeOrdered([big, mid])
    sched = plan_offload(ordered, min_idle_phases=30, min_bytes=1)
    assert sched.names() == ("X:big",)
    plan = plan_memory_swapped(ordered, sched)
    plan.validate()
    align = 1 << 20  # both tensors align to 1 MiB exactly
    assert plan.baseline_arena_bytes == 2 * align
    assert plan.arena_bytes == align          # mid reuses big's vacated bytes
    assert plan.host_pool_bytes == align
    assert plan.swapped_names() == ("X:big",)
    pre, post = sorted(plan.residencies["X:big"], key=lambda r: r.min_eo)
    d = sched.decisions[0]
    assert pre.max_eo == d.swap_out_eo
    assert post.min_eo == d.prefetch_at_eo


def test_swap_plan_validation_catches_tampering():
    big = _x("big", 1 << 20, (0, 50))
    mid = _x("mid", 1 << 20, (10, 20))
    ordered = _FakeOrdered([big, mid])
    sched = plan_offload(ordered, min_idle_phases=30, min_bytes=1)
    plan = plan_memory_swapped(ordered, sched)
    # stretch the pre-swap residency into the idle window: must be rejected
    pre, _ = sorted(plan.residencies["X:big"], key=lambda r: r.min_eo)
    pre.max_eo = 15
    with pytest.raises(AssertionError):
        plan.validate()


def test_non_vacating_candidates_never_scheduled():
    # idle window of 2 phases: swap-out at +1, prefetch at read-2 == +1,
    # so nothing would be reclaimed — the planner must not schedule it,
    # nor count its bytes as savings / toward the HBM budget
    t = _x("t", 1 << 20, (0, 3))
    ordered = _FakeOrdered([t])
    sched = plan_offload(ordered, min_idle_phases=2, min_bytes=1,
                         prefetch_margin=2)
    assert not sched.decisions
    assert sched.hbm_bytes_saved == 0 and sched.dma_bytes == 0


def test_non_vacating_decisions_stay_resident():
    # defensive path: a hand-built non-vacating decision reaching the
    # planner keeps the tensor whole (single residency interval)
    from repro.core.offload import OffloadDecision
    t = _x("t", 1 << 20, (0, 3))
    ordered = _FakeOrdered([t])
    d = OffloadDecision(name="X:t", nbytes=1 << 20, write_eo=0, read_eo=3,
                        prefetch_at_eo=1)
    sched = OffloadSchedule(decisions=(d,), hbm_bytes_saved=0, dma_bytes=0,
                            peak_inflight_prefetch=0)
    assert not d.vacates
    plan = plan_memory_swapped(ordered, sched)
    assert plan.swapped_names() == ()
    assert len(plan.residencies["X:t"]) == 1


@pytest.mark.parametrize("name,batch", [("vgg16", 16), ("resnet18", 16)])
def test_swap_peak_strictly_below_sorting_baseline(name, batch):
    """Acceptance: swap-aware arena peak strictly below no-swap sorting."""
    cp = compile_plan(
        ZOO[name](), MemoryPlanConfig(min_idle_phases=4, min_bytes=1 << 16),
        batch=batch)
    cp.plan.validate()
    assert cp.peak_bytes < cp.baseline.arena_bytes
    assert cp.hbm_bytes_saved > 0
    # co-optimisation never raises the peak above the single-pass plan
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes


def test_plan_memory_offload_kwarg_dispatches():
    ordered = compute_execution_order(ZOO["lenet5"](), 8)
    sched = plan_offload(ordered, min_idle_phases=4, min_bytes=1 << 12)
    plan = plan_memory(ordered, "sorting", offload=sched)
    assert plan.swapped_names()   # SwapAwarePlan, with actual swaps


# ---------------------------------------------------------------------------
# Swap executor: gradients vs jax.grad + HBM high-water vs planned peak
# ---------------------------------------------------------------------------

def _shrink(graph):
    for l in graph.layers:
        if l.attrs.get("in_features") == 150528:
            l.attrs["in_features"] = 96
    if graph.input_shape == (150528,):
        object.__setattr__(graph, "input_shape", (96,))
    from repro.core.graph import infer_shapes
    infer_shapes(graph)
    return graph


def _run_swap_case(g, batch, one_hot=False):
    # cooptimize=False: these cases exist to exercise the swap executor, so
    # keep even the swaps the fixed point would drop as non-load-bearing
    cp = compile_plan(
        g, MemoryPlanConfig(min_idle_phases=3, min_bytes=1,
                            prefetch_margin=2, cooptimize=False),
        batch=batch)
    assert cp.schedule.decisions, "case must actually exercise swapping"
    params = cp.init_params(jax.random.PRNGKey(0))
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
    y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
    if one_hot:
        y = jax.nn.one_hot(jnp.argmax(y, -1), y.shape[-1])
    loss_s, grads_s, stats = cp.loss_and_grads(params, x, y)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_s), float(loss_r), rtol=1e-5)
    la = jax.tree_util.tree_leaves(grads_s)
    lb = jax.tree_util.tree_leaves(grads_r)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    return stats


def test_swap_exec_grads_match_lenet5():
    stats = _run_swap_case(ZOO["lenet5"](), 4, one_hot=True)
    assert stats.swap_outs == stats.prefetches > 0
    assert stats.late_swap_ins == 0
    assert stats.hbm_high_water <= stats.planned_peak
    assert stats.dma_bytes > 0
    # host-pool residency is tracked alongside HBM and bounded by the
    # packed host arena
    assert 0 < stats.host_high_water <= stats.planned_host_pool


@pytest.mark.parametrize("host_planner",
                         ["sorting", "bestfit", "segregated", "buddy"])
def test_swap_exec_host_high_water_bounded_per_host_planner(host_planner):
    """Executor acceptance across the allocator layer: grads match
    jax.grad, HBM high-water <= planned peak, and the measured host-pool
    high-water stays within every packer's host_pool_bytes."""
    g = ZOO["lenet5"]()
    cp = compile_plan(
        g, MemoryPlanConfig(planner="bestfit", host_planner=host_planner,
                            min_idle_phases=3, min_bytes=1,
                            cooptimize=False), batch=4)
    assert cp.schedule.decisions
    params = init_params(g, jax.random.PRNGKey(0))
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (4,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.argmax(
        jax.random.normal(ky, (4,) + tuple(g.label_shape)), -1), 10)
    loss_s, grads_s, stats = cp.loss_and_grads(params, x, y)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_s), float(loss_r), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(grads_s),
                    jax.tree_util.tree_leaves(grads_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert stats.hbm_high_water <= stats.planned_peak <= cp.peak_bytes
    assert stats.host_high_water <= cp.host_pool_bytes
    assert stats.late_swap_ins == 0
    assert stats.replayed_ops == cp.lowered.ops


def test_pool_cd_read_is_a_recorded_access():
    """Max-pool backward re-reads its input at the pool's CD phase; the EO
    analysis must record that access or swaps race it (late swap-ins)."""
    g = ZOO["lenet5"]()
    ordered = compute_execution_order(g, 4)
    _, _, p1_cd = ordered.layer_orders["p1"]
    assert p1_cd in ordered.tensors["X:c1"].exec_orders
    # with the access recorded, even a zero-margin prefetch never misses
    ordered2 = compute_execution_order(g, 4)
    sched = plan_offload(ordered2, min_idle_phases=3, min_bytes=1,
                         prefetch_margin=1)
    params = init_params(g, jax.random.PRNGKey(0))
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (4,) + tuple(g.input_shape))
    y = jax.nn.one_hot(jnp.arange(4) % 10, 10)
    _, _, stats = swap_planned_loss_and_grads(
        g, params, x, y, schedule=sched, ordered=ordered2)
    assert stats.late_swap_ins == 0


def test_offload_dropped_with_no_budget_streams_everything():
    """The deprecated free-DMA alias must not silently no-op under the
    default (budget-less) config: no budget + offload == keep nothing on
    device — and its DMA traffic is now accounted, not zeroed."""
    from repro.core.remat_policy import (plan_checkpoint_policy,
                                         transformer_intermediates)
    inter = transformer_intermediates(
        batch_tokens=1024, d_model=256, d_ff=1024, n_q_heads=4,
        n_kv_heads=2, head_dim=64)
    with pytest.warns(DeprecationWarning):
        plan = plan_checkpoint_policy(inter, None, offload_dropped=True)
    assert set(plan.offloaded) == {i.name for i in inter}
    assert plan.saved == () and plan.dropped == ()
    assert plan.offload_dma_bytes_per_layer == \
        2 * sum(i.bytes_per_layer for i in inter)
    assert plan.policy() is not None


def test_swap_exec_grads_match_model_a():
    stats = _run_swap_case(_shrink(ZOO["model_a_linear"]()), 4)
    assert stats.late_swap_ins == 0
    assert stats.hbm_high_water <= stats.planned_peak


def test_swap_exec_grads_match_unrolled_lstm():
    g = ZOO["tacotron2_decoder"](time_steps=4, mel_dim=8, prenet_dim=8,
                                 lstm_dim=8)
    stats = _run_swap_case(g, 2)
    assert stats.late_swap_ins == 0


def test_swap_exec_empty_schedule_is_plain_planned_exec():
    g = _shrink(ZOO["model_b_linear"]())
    ordered = compute_execution_order(g, 4)
    empty = OffloadSchedule(decisions=(), hbm_bytes_saved=0, dma_bytes=0,
                            peak_inflight_prefetch=0)
    params = init_params(g, jax.random.PRNGKey(0))
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (4,) + tuple(g.input_shape))
    y = jax.random.normal(ky, (4,) + tuple(g.label_shape))
    loss_s, grads_s, stats = swap_planned_loss_and_grads(
        g, params, x, y, schedule=empty, ordered=ordered)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_s), float(loss_r), rtol=1e-5)
    assert stats.swap_outs == stats.prefetches == stats.dma_bytes == 0
