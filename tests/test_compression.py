"""The int8 block-quantised compression core (``repro.optim.compression``)
and the optimizer-state quantiser's zero-absmax guard
(``repro.optim.optimizers._quantize``).

These primitives back two subsystems — cross-pod gradient compression and
the planner-managed optimizer-state offload's host copies — so their
contracts are pinned here: round-trip error bounds, error-feedback
residual algebra, the padded tail when n is not a CBLOCK multiple, and
the all-zero block that must not divide by zero.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import (CBLOCK, _deq, _q, compress_gradients,
                                     decompress_gradients,
                                     error_feedback_update, init_residual)
from repro.optim.optimizers import _dequantize, _quantize


# ---------------------------------------------------------------------------
# _q / _deq round-trip bounds
# ---------------------------------------------------------------------------

def test_q_deq_roundtrip_error_bounded_per_block():
    # absmax int8: |x - deq(q(x))| <= scale/2 = max|block| / 254 per block
    x = jax.random.normal(jax.random.PRNGKey(0), (4 * CBLOCK,))
    q, scale = _q(x)
    back = _deq(q, scale, x.shape)
    assert back.shape == x.shape
    err = np.abs(np.asarray(x - back)).reshape(4, CBLOCK)
    bound = np.max(np.abs(np.asarray(x).reshape(4, CBLOCK)),
                   axis=1, keepdims=True) / 254.0
    assert np.all(err <= bound + 1e-7)


def test_q_deq_exact_on_representable_values():
    # multiples of absmax/127 are exactly representable
    scale_true = 0.5
    x = jnp.arange(-127, 129, dtype=jnp.float32) * scale_true
    x = x.at[-1].set(0.0)  # keep absmax at 127*scale so the grid matches
    q, scale = _q(x)
    np.testing.assert_allclose(np.asarray(_deq(q, scale, x.shape)),
                               np.asarray(x), atol=1e-6)


def test_q_deq_padded_tail_not_multiple_of_cblock():
    # n % CBLOCK != 0: the pad must stay internal — shape and values of
    # the tail round-trip, and the pad zeros never leak into the output
    n = CBLOCK + 37
    x = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 3.0
    q, scale = _q(x)
    assert q.shape == (2, CBLOCK)          # padded to 2 blocks
    back = _deq(q, scale, (n,))
    assert back.shape == (n,)
    assert float(jnp.max(jnp.abs(x - back))) <= float(
        jnp.max(jnp.abs(x))) / 127.0


def test_q_deq_multidim_shape_restored():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 7))
    q, scale = _q(x)
    back = _deq(q, scale, x.shape)
    assert back.shape == (3, 5, 7)
    assert float(jnp.max(jnp.abs(x - back))) < float(jnp.max(jnp.abs(x)))


def test_q_all_zero_block_yields_unit_scale_and_zero_roundtrip():
    x = jnp.zeros((CBLOCK * 2,))
    q, scale = _q(x)
    assert np.all(np.asarray(scale) == 1.0)      # guard, not 0/0
    assert np.all(np.asarray(_deq(q, scale, x.shape)) == 0.0)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_residual_is_exact_quantisation_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (CBLOCK,))}
    e0 = init_residual(g)
    c, e1 = error_feedback_update(g, e0)
    deq = _deq(c["w"]["q"], c["w"]["scale"], g["w"].shape)
    np.testing.assert_allclose(np.asarray(e1["w"]),
                               np.asarray(g["w"] - deq), atol=1e-7)


def test_error_feedback_accumulates_unbiased_over_steps():
    # a constant gradient stream: with EF the *sum* of dequantised
    # emissions tracks the sum of true gradients to within one step's
    # quantisation error — the residual never grows without bound
    g = {"w": jax.random.normal(jax.random.PRNGKey(4), (CBLOCK,)) * 1e-3}
    e = init_residual(g)
    emitted = jnp.zeros_like(g["w"])
    steps = 16
    for _ in range(steps):
        c, e = error_feedback_update(g, e)
        emitted = emitted + _deq(c["w"]["q"], c["w"]["scale"], g["w"].shape)
    true_sum = g["w"] * steps
    one_step_bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0 * 2
    assert float(jnp.max(jnp.abs(emitted - true_sum))) \
        <= one_step_bound + float(jnp.max(jnp.abs(e["w"])))
    # and the residual itself stays at quantisation-noise scale
    assert float(jnp.max(jnp.abs(e["w"]))) \
        <= float(jnp.max(jnp.abs(g["w"] + e["w"]))) / 127.0 + 1e-7


def test_error_feedback_recovers_subquantisation_signal():
    # a signal too small for one quantisation step is dropped at step 1
    # but the residual accumulates it until it crosses the grid: the EF
    # path must emit nonzero mass where a memoryless quantiser never would
    big = 1.0
    tiny = big / 500.0                     # < absmax/127 — rounds to 0
    g = {"w": jnp.concatenate([jnp.array([big]),
                               jnp.full((CBLOCK - 1,), tiny)])}
    e = init_residual(g)
    emitted = jnp.zeros_like(g["w"])
    for _ in range(8):
        c, e = error_feedback_update(g, e)
        emitted = emitted + _deq(c["w"]["q"], c["w"]["scale"], g["w"].shape)
    assert float(jnp.max(emitted[1:])) > 0.0


def test_compress_decompress_tree_roundtrip():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(5), (10, 30)),
            "b": {"c": jax.random.normal(jax.random.PRNGKey(6), (7,))}}
    out = decompress_gradients(compress_gradients(tree), tree)
    for k, leaf in (("a", tree["a"]), ("c", tree["b"]["c"])):
        got = out[k] if k == "a" else out["b"]["c"]
        assert got.shape == leaf.shape
        assert float(jnp.max(jnp.abs(got - leaf))) \
            <= float(jnp.max(jnp.abs(leaf))) / 127.0 + 1e-7


# ---------------------------------------------------------------------------
# optimizers._quantize zero-absmax guard (regression)
# ---------------------------------------------------------------------------

def test_quantize_zero_init_state_regression():
    # freshly-initialised optimizer state is all zeros; quantising it must
    # not divide by zero (scale guard) and must round-trip to exact zeros,
    # or the first offloaded AdamW step would start from NaN moments
    m = jnp.zeros((1000,))
    qm = _quantize(m)
    back = _dequantize(qm, m.shape)
    assert not bool(jnp.any(jnp.isnan(back)))
    assert np.all(np.asarray(back) == 0.0)
    assert back.shape == m.shape


def test_quantize_mixed_zero_and_live_blocks():
    # one all-zero block next to a live block: the guard must only touch
    # the degenerate block's scale, leaving the live block's values intact
    from repro.optim.optimizers import QBLOCK
    x = jnp.concatenate([jnp.zeros((QBLOCK,)),
                         jax.random.normal(jax.random.PRNGKey(7), (QBLOCK,))])
    back = _dequantize(_quantize(x), x.shape)
    assert np.all(np.asarray(back[:QBLOCK]) == 0.0)
    live_err = float(jnp.max(jnp.abs(back[QBLOCK:] - x[QBLOCK:])))
    assert live_err <= float(jnp.max(jnp.abs(x[QBLOCK:]))) / 127.0 + 1e-7
