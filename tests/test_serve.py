"""Multi-tenant personalization serving: buckets + pad-to-bucket numerics,
the budget-keyed compile cache, admission control, fault-injection kills
releasing arena reservations, shared-plan QoS acceptance, the
phase-interleaved multi-session scheduler, and the batched LM prefill
path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ArenaBudgetError, MemoryPlanConfig, compile_plan,
                        compile_plan_under_budget)
from repro.core.exec.layers import init_params, reference_loss_and_grads
from repro.core.verify import (ScheduleVerificationError, SessionArenaSlice,
                               verify_interleaving)
from repro.core.zoo import ZOO
from repro.runtime.fault import FaultInjector
from repro.serve import (AdmissionController, PersonalizationService,
                         PlanCache, QosClass, ServablePersonalizer,
                         SessionWork, StepScheduler, choose_bucket,
                         dummy_batch, pad_to_bucket)

CFG = MemoryPlanConfig(min_idle_phases=3, min_bytes=1 << 12)


# ---------------------------------------------------------------------------
# Buckets and padding
# ---------------------------------------------------------------------------

def test_choose_bucket_smallest_fit():
    assert choose_bucket(1, (8, 16)) == 8
    assert choose_bucket(8, (16, 8)) == 8      # order-insensitive
    assert choose_bucket(9, (8, 16)) == 16
    assert choose_bucket(17, (8, 16)) is None
    assert choose_bucket(0, (8, 16)) is None


def test_pad_to_bucket_shapes_and_mask():
    g = ZOO["lenet5"]()
    x, y = dummy_batch(g, 5, seed=0)
    xp, yp, mask = pad_to_bucket(x, y, 8)
    assert xp.shape == (8,) + tuple(g.input_shape)
    assert yp.shape == (8,) + tuple(g.label_shape)
    assert mask.shape == (8,)
    np.testing.assert_array_equal(np.asarray(mask), [1, 1, 1, 1, 1, 0, 0, 0])
    # full batch passes through untouched, no mask
    x8, y8 = dummy_batch(g, 8, seed=0)
    xf, yf, mf = pad_to_bucket(x8, y8, 8)
    assert xf is x8 and yf is y8 and mf is None
    with pytest.raises(ValueError):
        pad_to_bucket(x8, y8, 4)


@pytest.mark.parametrize("name,n,bucket", [
    ("lenet5", 5, 8),
    ("model_b_conv2d", 3, 8),
])
def test_padded_bucket_grads_match_unpadded(name, n, bucket):
    """Masked padded-bucket gradients == unpadded gradients to 1e-4, and
    both match the jax.grad reference — padding is numerically free."""
    g = ZOO[name]()
    params = init_params(g, jax.random.PRNGKey(0))
    x, y = dummy_batch(g, n, seed=3)
    cp_n = compile_plan(g, CFG, batch=n)
    loss_ref, grads_ref = cp_n.loss_and_grads(params, x, y)[:2]

    cp_b = compile_plan(g, CFG, batch=bucket)
    xp, yp, mask = pad_to_bucket(x, y, bucket)
    loss_pad, grads_pad, _ = cp_b.loss_and_grads(params, xp, yp, mask=mask)

    np.testing.assert_allclose(float(loss_pad), float(loss_ref),
                               rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(grads_pad),
                    jax.tree_util.tree_leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    # and the masked planned path matches the masked autodiff reference
    ref_loss, ref_grads = reference_loss_and_grads(g, params, xp, yp,
                                                   mask=mask)
    np.testing.assert_allclose(float(loss_pad), float(ref_loss),
                               rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(grads_pad),
                    jax.tree_util.tree_leaves(ref_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# The compile cache: full-config keying
# ---------------------------------------------------------------------------

def test_plan_cache_hit_and_miss_counters():
    g = ZOO["lenet5"]()
    cache = PlanCache()
    cp1 = cache.get_or_compile(g, CFG, bucket=8)
    cp2 = cache.get_or_compile(g, CFG, bucket=8)
    assert cp1 is cp2
    assert (cache.hits, cache.misses) == (1, 1)
    cache.get_or_compile(g, CFG, bucket=16)
    assert (cache.hits, cache.misses) == (1, 2)
    assert len(cache) == 2


def test_plan_cache_no_collision_across_configs_or_budgets():
    """Deliberate collision attempt: same model, same bucket, configs that
    differ in exactly one QoS-relevant knob must get distinct plans."""
    g = ZOO["lenet5"]()
    cache = PlanCache()
    base = cache.get_or_compile(g, CFG, bucket=8)
    # different planner knob -> different key, fresh compile
    other_cfg = cache.get_or_compile(
        g, MemoryPlanConfig(min_idle_phases=2, min_bytes=1 << 12), bucket=8)
    assert other_cfg is not base
    # same config, different arena budget -> different key too: tenants
    # with different QoS budgets can never share a plan
    budget = base.peak_bytes + (1 << 20)
    other_budget = cache.get_or_compile(g, CFG, bucket=8,
                                        arena_budget_bytes=budget)
    assert other_budget is not base
    assert cache.hits == 0 and cache.misses == 3
    # every distinct MemoryPlanConfig field lands in the key
    k1 = CFG.cache_key()
    k2 = MemoryPlanConfig(min_idle_phases=2, min_bytes=1 << 12).cache_key()
    assert k1 != k2
    assert len(k1) == len(k2)  # all fields, stable arity


def test_compile_plan_under_budget_escalates_and_rejects():
    g = ZOO["lenet5"]()
    base = compile_plan(g, MemoryPlanConfig(swap=False), batch=8)
    # a 90% budget needs the escalation ladder, and the plan must verify
    cp = compile_plan_under_budget(
        g, MemoryPlanConfig(), batch=8,
        arena_budget_bytes=int(base.peak_bytes * 0.9))
    assert cp.peak_bytes <= int(base.peak_bytes * 0.9)
    assert cp.verify_report.ok
    # an impossible budget raises with the best attempt attached
    with pytest.raises(ArenaBudgetError) as ei:
        compile_plan_under_budget(g, MemoryPlanConfig(), batch=8,
                                  arena_budget_bytes=1 << 10)
    assert ei.value.best_peak_bytes > ei.value.arena_budget_bytes == 1 << 10


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_admission_slots_shares_release():
    ac = AdmissionController(max_live_sessions=2,
                             device_budget_bytes=1000)
    assert ac.arena_share_bytes == 500
    assert ac.try_admit("a") == 500
    assert ac.try_admit("a") == 500          # idempotent, no double booking
    assert ac.reserved_bytes == 500
    assert ac.try_admit("b") == 500
    assert ac.try_admit("c") is None         # full
    assert ac.rejections == 1
    assert ac.release("b") and not ac.release("b")
    assert ac.try_admit("c") == 500          # freed slot reusable
    assert ac.live == ("a", "c")


def test_service_rejects_gracefully_and_recovers():
    g = ZOO["lenet5"]()
    svc = PersonalizationService(g, buckets=(8,), max_live_sessions=1,
                                 config=CFG)
    r1 = svc.submit("alice", *dummy_batch(g, 8, seed=0))
    assert r1.ok
    r2 = svc.submit("bob", *dummy_batch(g, 8, seed=1))
    assert r2.status == "rejected" and "slot" in r2.reason
    assert svc.stats.rejected_admission == 1
    assert svc.stats.deadlocks == 0
    # ending alice's session frees the slot for bob
    assert svc.end_session("alice")
    r3 = svc.submit("bob", *dummy_batch(g, 8, seed=1))
    assert r3.ok


def test_killed_session_releases_arena_reservation():
    """ISSUE satellite: a session killed mid-queue must release its arena
    reservation via the runtime/fault.py injection hook."""
    g = ZOO["lenet5"]()
    inj = FaultInjector()
    svc = PersonalizationService(g, buckets=(8,), max_live_sessions=2,
                                 config=CFG, injector=inj)
    assert svc.submit("alice", *dummy_batch(g, 8, seed=0)).ok
    assert svc.submit("bob", *dummy_batch(g, 8, seed=1)).ok
    reserved = svc.admission.reserved_bytes
    assert reserved == 2 * svc.admission.arena_share_bytes

    # arm the kill, then queue two requests behind it: the kill fires at
    # the dequeue of alice's next request, before any step runs
    inj.arm_kill("session:alice")
    svc.enqueue("alice", *dummy_batch(g, 8, seed=2))
    svc.enqueue("carol", *dummy_batch(g, 8, seed=3))
    results = svc.drain()
    assert results[0].status == "killed"
    assert "released" in results[0].reason
    assert inj.fired == ["session:alice"]
    # the freed reservation admitted carol within the same drain
    assert results[1].ok
    assert svc.admission.reserved_bytes == reserved
    assert "alice" not in svc.servable.sessions
    assert svc.stats.killed == 1


# ---------------------------------------------------------------------------
# Shared plans + per-session state
# ---------------------------------------------------------------------------

def test_sessions_share_base_but_diverge_personally():
    g = ZOO["lenet5"]()
    sv = ServablePersonalizer(g, lr=0.02)
    cp = compile_plan(g, CFG, batch=8)
    a = sv.open_session("a", cp.peak_bytes)
    b = sv.open_session("b", cp.peak_bytes)
    x, y = dummy_batch(g, 8, seed=0)
    sv.train_step(a, cp, x, y)
    # a trained, b did not: b still aliases the frozen base tree
    for owner in sv.trainable_owners:
        for k, w in sv.base_params[owner].items():
            assert b.params[owner][k] is w
            assert not np.allclose(np.asarray(a.params[owner][k]),
                                   np.asarray(w))
    assert a.step == 1 and b.step == 0
    # training drives the loss down through the shared plan
    losses = [sv.train_step(a, cp, x, y)[0] for _ in range(10)]
    assert losses[-1] < losses[0]


def test_acceptance_eight_sessions_two_buckets():
    """ISSUE acceptance: 8 concurrent sessions over 2 buckets share
    compiled plans (hit rate >= 6/8), every admitted session's measured
    peak stays within its arena share, and the replayed schedules passed
    repro.core.verify at compile time."""
    g = ZOO["lenet5"]()
    svc = PersonalizationService(g, buckets=(8, 16), max_live_sessions=8,
                                 config=CFG)
    svc.warmup()
    for u in range(8):
        n = 6 if u % 2 else 14
        res = svc.submit(f"u{u}", *dummy_batch(g, n, seed=u))
        assert res.ok, res.reason
        assert res.peak_bytes <= res.arena_share_bytes
    rep = svc.report()
    assert rep["serve"]["completed"] == 8
    # 2 warm-up misses, 8 session first-steps all hit
    assert rep["plan_cache"]["hits"] >= 6
    assert rep["plan_cache"]["entries"] == 2
    assert all(s["within_share"]
               for s in rep["serve"]["sessions"].values())
    # every cached plan passed the static verifier before any replay
    for cp in svc.cache._plans.values():
        assert cp.verify_report is not None and cp.verify_report.ok


def test_tight_budget_squeezes_plans_or_rejects():
    """The planner is the QoS lever: an explicit budget below the no-swap
    peak forces smaller plans; an impossible one rejects at warmup."""
    g = ZOO["lenet5"]()
    base = compile_plan(g, MemoryPlanConfig(swap=False), batch=8)
    share = int(base.peak_bytes * 0.9)
    svc = PersonalizationService(g, buckets=(8,), max_live_sessions=2,
                                 device_budget_bytes=2 * share, config=CFG)
    svc.warmup()
    res = svc.submit("a", *dummy_batch(g, 8, seed=0))
    assert res.ok
    assert res.arena_share_bytes == share
    assert res.peak_bytes <= share
    with pytest.raises(ArenaBudgetError):
        PersonalizationService(g, buckets=(8,), max_live_sessions=2,
                               device_budget_bytes=2 << 10,
                               config=CFG).warmup()


# ---------------------------------------------------------------------------
# Phase-interleaved multi-session execution
# ---------------------------------------------------------------------------

def _works(g, cp, users, *, qos="standard", weight=1.0, seed0=0):
    """SessionWork items over disjoint fixed shares for scheduler tests."""
    share = cp.peak_bytes + cp.optim_device_bytes
    out = []
    for i, u in enumerate(users):
        x, y = dummy_batch(g, 8, seed=seed0 + i)
        params = init_params(g, jax.random.PRNGKey(seed0 + i))
        out.append(SessionWork(
            user=u, arrival=i + 1, qos=qos, weight=weight,
            base_offset=i * share, share_bytes=share, cp=cp,
            x=x, y=y, mask=None, params_fn=lambda p=params: p))
    return out


def test_scheduler_interleaves_sessions_with_correct_grads():
    """ISSUE tentpole: N sessions round-robin at phase boundaries over one
    shared device stream; every session's grads still match jax.grad, its
    replayed stream equals the compiled op list, and cross-session DMA
    overlap is measured (not asserted into existence)."""
    g = ZOO["lenet5"]()
    cp = compile_plan(g, CFG, batch=8)
    works = _works(g, cp, ["a", "b", "c"])
    sched = StepScheduler()
    outs = sched.run(works)
    assert [o.user for o in outs] == ["a", "b", "c"]   # arrival order
    for w, o in zip(works, outs):
        assert o.ok
        ref_loss, ref_grads = reference_loss_and_grads(
            g, w.params_fn(), w.x, w.y)
        np.testing.assert_allclose(o.loss, float(ref_loss),
                                   rtol=1e-4, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(o.grads),
                        jax.tree_util.tree_leaves(ref_grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        # replay fidelity: the interleaved cursor drove the exact op list
        assert o.stats.replayed_ops == cp.lowered.ops
        assert o.stats.hbm_high_water <= w.share_bytes
        assert o.stats.cross_hidden_dma_s >= 0.0
    rep = sched.report()
    assert rep["sessions"] == 3 and rep["completed"] == 3
    assert rep["verify_errors"] == 0
    assert rep["rounds"] > 1                  # genuinely interleaved
    # the shared engine really moved bytes for all three sessions
    assert rep["hidden_dma_s"] + rep["exposed_dma_s"] > 0.0


def test_scheduler_rejects_overlapping_shares_and_duplicate_users():
    g = ZOO["lenet5"]()
    cp = compile_plan(g, CFG, batch=8)
    works = _works(g, cp, ["a", "b"])
    bad = dataclasses_replace_base(works[1], works[0].base_offset)
    with pytest.raises(ScheduleVerificationError) as ei:
        StepScheduler().run([works[0], bad])
    assert any(d.check == "cross_session_arena"
               for d in ei.value.diagnostics)
    dup = _works(g, cp, ["a", "a"])
    with pytest.raises(ValueError):
        StepScheduler().run(dup)


def dataclasses_replace_base(w, base):
    import dataclasses
    return dataclasses.replace(w, base_offset=base)


def test_verify_interleaving_unit():
    sl = [SessionArenaSlice("a", "standard", 0, 1000, 900),
          SessionArenaSlice("b", "standard", 1000, 1000, 1000)]
    assert verify_interleaving(sl).ok
    # overlap: b starts inside a's share
    bad = [sl[0], SessionArenaSlice("b", "standard", 500, 1000, 900)]
    rep = verify_interleaving(bad)
    assert not rep.ok and "cross_session_arena" in rep.check_ids()
    # peak overflows its own share
    over = [SessionArenaSlice("a", "standard", 0, 1000, 1001)]
    assert not verify_interleaving(over).ok


def test_scheduler_kill_mid_step_releases_survivors_unharmed():
    """ISSUE satellite: FaultInjector kills a session mid-step at a phase
    boundary; its cursor/engine state is torn down and the surviving
    sessions complete with correct grads."""
    g = ZOO["lenet5"]()
    cp = compile_plan(g, CFG, batch=8)
    works = _works(g, cp, ["a", "b", "c"])
    inj = FaultInjector()
    inj.arm_kill("session:b", after=1)        # fires at the 2nd boundary
    sched = StepScheduler(injector=inj)
    outs = sched.run(works)
    by_user = {o.user: o for o in outs}
    assert by_user["b"].status == "killed"
    assert "phase boundary" in by_user["b"].reason
    assert inj.fired == ["session:b"]
    # the aborted cursor drained its in-flight DMA: nothing leaks into
    # the shared engine the survivors keep using
    assert not sched.engine._inflight and not sched.engine._opt_inflight
    for u in ("a", "c"):
        o = by_user[u]
        assert o.ok
        w = next(w for w in works if w.user == u)
        _, ref_grads = reference_loss_and_grads(g, w.params_fn(), w.x, w.y)
        for x, y in zip(jax.tree_util.tree_leaves(o.grads),
                        jax.tree_util.tree_leaves(ref_grads)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-5)
    assert sched.report()["killed"] == 1


def test_service_kill_at_phase_boundary_releases_reservation():
    """Service-level: the phase-boundary kill (not the dequeue kill)
    releases the arena reservation + host-pool state, the scheduler
    cursor is gone, and the same user can re-admit and train."""
    g = ZOO["lenet5"]()
    inj = FaultInjector()
    svc = PersonalizationService(g, buckets=(8,), max_live_sessions=2,
                                 config=CFG, injector=inj)
    svc.warmup()
    # after=1: survives the dequeue check, fires at the first scheduler
    # round -> a genuine mid-step kill at a phase boundary
    inj.arm_kill("session:bob", after=1)
    svc.enqueue("alice", *dummy_batch(g, 8, seed=0))
    svc.enqueue("bob", *dummy_batch(g, 8, seed=1))
    r_alice, r_bob = svc.drain()
    assert r_alice.ok
    assert r_bob.status == "killed"
    assert "phase boundary" in r_bob.reason
    assert "released" in r_bob.reason
    assert "bob" not in svc.admission.live
    assert "bob" not in svc.servable.sessions
    assert svc.stats.killed == 1
    # the slot is reusable: bob re-admits and completes
    r2 = svc.submit("bob", *dummy_batch(g, 8, seed=2))
    assert r2.ok, r2.reason


def test_interleaved_service_matches_fifo_numerics():
    """The interleaved drain is an execution-order optimization only:
    the same traffic produces the same losses as the FIFO baseline."""
    g = ZOO["lenet5"]()
    results = {}
    for interleave in (False, True):
        svc = PersonalizationService(g, buckets=(8,), max_live_sessions=3,
                                     config=CFG, interleave=interleave)
        svc.warmup()
        for u in range(3):
            svc.enqueue(f"u{u}", *dummy_batch(g, 8, seed=u))
        results[interleave] = svc.drain()
    for fifo, inter in zip(results[False], results[True]):
        assert fifo.ok and inter.ok
        assert fifo.user == inter.user
        np.testing.assert_allclose(inter.loss, fifo.loss,
                                   rtol=1e-5, atol=1e-6)


def test_qos_classes_price_shares_and_gate_admission():
    """ISSUE satellite: fixed shares grow into weighted QoS classes; the
    premium class buys a proportionally larger share, slots gate per
    class, and the partition stays provably disjoint."""
    ac = AdmissionController(
        max_live_sessions=3, device_budget_bytes=4000,
        qos=(QosClass("premium", weight=2.0, slots=1),
             QosClass("standard", weight=1.0, slots=2)))
    assert ac.share_for("premium") == 2000
    assert ac.share_for("standard") == 1000
    assert ac.default_qos == "premium"
    assert ac.try_admit("p", qos="premium") == 2000
    assert ac.base_offset("p") == 0
    assert ac.try_admit("s1", qos="standard") == 1000
    assert ac.try_admit("s2", qos="standard") == 1000
    assert sorted(ac.base_offset(u) for u in ("s1", "s2")) == [2000, 3000]
    # class full: premium rejects even though standard slots are gone too
    assert ac.try_admit("p2", qos="premium") is None
    assert ac.rejections_by_class["premium"] == 1
    # re-admission must not contradict the live class
    with pytest.raises(ValueError):
        ac.try_admit("p", qos="standard")
    # the live partition proves disjoint
    assert verify_interleaving(ac.arena_slices()).ok
    # released premium slot returns to its own class pool
    assert ac.release("p")
    assert ac.try_admit("p2", qos="premium") == 2000
    rep = ac.report()
    assert rep["qos"]["premium"]["share_bytes"] == 2000
    assert rep["qos"]["standard"]["live"] == 2


def test_qos_weighted_rounds_and_starvation_accounting():
    """A weight-2 session takes two phase advances per round; every extra
    advance is charged to the waiting classes' bypassed_phases."""
    from repro.serve import ServeStats
    g = ZOO["lenet5"]()
    cp = compile_plan(g, CFG, batch=8)
    share = cp.peak_bytes + cp.optim_device_bytes
    x, y = dummy_batch(g, 8, seed=0)
    params = init_params(g, jax.random.PRNGKey(0))
    works = [
        SessionWork(user="prem", arrival=1, qos="premium", weight=2.0,
                    base_offset=0, share_bytes=share, cp=cp, x=x, y=y,
                    mask=None, params_fn=lambda: params),
        SessionWork(user="std", arrival=2, qos="standard", weight=1.0,
                    base_offset=share, share_bytes=share, cp=cp, x=x, y=y,
                    mask=None, params_fn=lambda: params),
    ]
    stats = ServeStats()
    outs = StepScheduler().run(works, stats)
    assert all(o.ok for o in outs)
    # the premium session finishes in ~half the rounds, so the standard
    # session was bypassed once per shared round
    assert stats.qos_stats("standard").bypassed_phases > 0
    assert stats.qos_stats("premium").bypassed_phases == 0


def test_service_queue_wait_and_deterministic_tie_break():
    """ISSUE satellite: per-request queue wait is measured and folded into
    per-QoS-class stats; equal-weight sessions resolve ties by global
    arrival order, deterministically across drains."""
    g = ZOO["lenet5"]()
    svc = PersonalizationService(g, buckets=(8,), max_live_sessions=4,
                                 config=CFG)
    svc.warmup()
    for u in ("w", "x", "y", "z"):
        svc.enqueue(u, *dummy_batch(g, 8, seed=ord(u)))
    results = svc.drain()
    # results come back in arrival order — the tie-break is the global
    # arrival sequence, not dict/hash order
    assert [r.user for r in results] == ["w", "x", "y", "z"]
    for r in results:
        assert r.ok and r.queue_wait_s >= 0.0
    rep = svc.report()["serve"]
    assert rep["queue_wait_s_total"] >= 0.0
    assert rep["queue_wait_high_water_s"] <= rep["queue_wait_s_total"] \
        or len(results) == 1
    q = rep["by_qos"]["standard"]
    assert q["completed"] == 4
    assert q["queue_wait_s_total"] >= q["queue_wait_high_water_s"] >= 0.0
    # a second identical drain orders identically (determinism)
    for u in ("w", "x", "y", "z"):
        svc.enqueue(u, *dummy_batch(g, 8, seed=ord(u)))
    assert [r.user for r in svc.drain()] == ["w", "x", "y", "z"]


def test_service_with_qos_classes_end_to_end():
    """Premium tenants get a larger share (bigger plans, fewer swaps) and
    both classes' measured peaks stay inside their priced shares."""
    g = ZOO["lenet5"]()
    svc = PersonalizationService(
        g, buckets=(8,), max_live_sessions=3, config=CFG,
        qos=(QosClass("premium", weight=2.0, slots=1),
             QosClass("standard", weight=1.0, slots=2)))
    svc.warmup()
    assert svc.admission.share_for("premium") \
        > svc.admission.share_for("standard")
    rp = svc.submit("p", *dummy_batch(g, 8, seed=0), qos="premium")
    rs = svc.submit("s", *dummy_batch(g, 8, seed=1), qos="standard")
    assert rp.ok and rs.ok
    assert rp.qos == "premium" and rs.qos == "standard"
    assert rp.arena_share_bytes > rs.arena_share_bytes
    assert rp.peak_bytes <= rp.arena_share_bytes
    assert rs.peak_bytes <= rs.arena_share_bytes
    # unknown class rejected loudly at enqueue
    with pytest.raises(KeyError):
        svc.enqueue("q", *dummy_batch(g, 8, seed=2), qos="gold")


# ---------------------------------------------------------------------------
# Batched LM prefill
# ---------------------------------------------------------------------------

def test_lm_prefill_matches_sequential_fill():
    """One fused prefill forward == S sequential decode steps: same cache,
    same last-position logits, same continuation."""
    from repro.configs import ARCHS
    from repro.models.model import build_model, reduce_config

    cfg = reduce_config(ARCHS["llama3.2-3b"])
    model = build_model(cfg)
    assert model.prefill_fn is not None
    params = model.init(jax.random.PRNGKey(0))
    b, plen, max_seq = 2, 10, 20
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (b, plen), dtype=np.int32))

    seq_state = model.decode_init(b, max_seq)
    logits_seq = None
    for t in range(plen):
        logits_seq, seq_state = model.decode_fn(
            params, seq_state, prompts[:, t], jnp.full((b,), t, jnp.int32))

    pre_state = model.decode_init(b, max_seq)
    logits_pre, pre_state = model.prefill_fn(params, pre_state, prompts)

    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_seq), rtol=1e-4, atol=1e-4)
    for key in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(pre_state[key], dtype=np.float32),
            np.asarray(seq_state[key], dtype=np.float32),
            rtol=1e-4, atol=1e-4)
    # both caches continue decoding identically
    cur = jnp.argmax(logits_pre[:, :cfg.vocab], -1).astype(jnp.int32)
    pos = jnp.full((b,), plen, jnp.int32)
    l_seq, _ = model.decode_fn(params, seq_state, cur, pos)
    l_pre, _ = model.decode_fn(params, pre_state, cur, pos)
    np.testing.assert_allclose(np.asarray(l_pre), np.asarray(l_seq),
                               rtol=1e-4, atol=1e-4)


def test_prefill_fn_only_on_kv_cache_families():
    from repro.configs import ARCHS
    from repro.models.model import build_model, reduce_config

    assert build_model(reduce_config(ARCHS["phi4-mini-3.8b"])).prefill_fn \
        is not None
    # recurrent-state family has no fused prefill: servers fall back to
    # the sequential token loop
    ssm = [a for a, c in ARCHS.items() if c.family == "ssm"]
    if ssm:
        assert build_model(reduce_config(ARCHS[ssm[0]])).prefill_fn is None


# ---------------------------------------------------------------------------
# FaultInjector mechanics
# ---------------------------------------------------------------------------

def test_fault_injector_counts_down_and_fires_once():
    inj = FaultInjector()
    inj.arm_kill("session:x", after=2)
    assert not inj.check("session:x")
    assert not inj.check("session:y")        # unrelated target untouched
    assert not inj.check("session:x")
    assert inj.check("session:x")            # third check fires
    assert not inj.check("session:x")        # one-shot
    assert inj.fired == ["session:x"]
    assert inj.armed == ()
