"""The pluggable allocator layer: ArenaAllocator implementations
(segregated-fit size classes, binary buddy), the ALIGN validation
contract, the swap-aware same-offset placement pass, and the
in-place-prefetch elision that removes copies from the host pool.
"""

import dataclasses

import pytest

from repro.core.execution_order import compute_execution_order
from repro.core.ideal import ideal_from_ordered
from repro.core.lifespan import CreateMode, Lifespan, TensorSpec
from repro.core.offload import OffloadDecision, make_schedule, plan_offload
from repro.core.plan import MemoryPlanConfig, compile_plan
from repro.core.planner import (ALIGN, PLANNERS, ArenaAllocator,
                                BuddyPlanner, Placement, Plan,
                                SegregatedFitPlanner, SortingPlanner,
                                get_planner, plan_memory_swapped)
from repro.core.zoo import ZOO


class _FakeOrdered:
    def __init__(self, tensors, eo_max=100):
        self.tensors = {t.name: t for t in tensors}
        self.merged = {}
        self.eo_max = eo_max
        self.layer_orders = {}

    def planned_tensors(self):
        return [t for t in self.tensors.values()
                if t.create_mode == CreateMode.CREATE]


def _t(name, nbytes, orders):
    t = TensorSpec(name=name, shape=(nbytes,), dtype="uint8",
                   lifespan=Lifespan.FORWARD, create_mode=CreateMode.CREATE)
    t.exec_orders = tuple(sorted(orders))
    return t


# ---------------------------------------------------------------------------
# Protocol + registry
# ---------------------------------------------------------------------------

def test_every_registered_planner_satisfies_the_protocol():
    for name, cls in PLANNERS.items():
        inst = cls()
        assert isinstance(inst, ArenaAllocator), name
        assert inst.name == name


def test_get_planner_unknown_name_is_a_clear_valueerror():
    with pytest.raises(ValueError, match="unknown planner 'tlsf'"):
        get_planner("tlsf")
    # the message names the valid choices
    with pytest.raises(ValueError, match="buddy"):
        get_planner("tlsf")


# ---------------------------------------------------------------------------
# Soundness: every allocator packs every zoo model validly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("planner", ["segregated", "buddy"])
@pytest.mark.parametrize("name", ["lenet5", "vgg16", "resnet18", "model_d",
                                  "tacotron2_decoder"])
def test_new_allocators_pack_zoo_models_validly(planner, name):
    ordered = compute_execution_order(ZOO[name](), 8)
    plan = get_planner(planner).plan(ordered)
    plan.validate()   # overlap-freedom + ALIGN + arena bound
    ideal = ideal_from_ordered(compute_execution_order(ZOO[name](), 8))
    assert plan.arena_bytes >= ideal.arena_bytes
    assert 0.0 < plan.utilization() <= 1.0


def test_segregated_reuses_within_class_across_disjoint_lifetimes():
    # two same-class tensors with disjoint lifetimes share one slot; the
    # third (live with the first) needs its own
    tensors = [_t("a", 1000, (0, 10)), _t("b", 900, (20, 30)),
               _t("c", 1000, (0, 30))]
    plan = SegregatedFitPlanner().plan(_FakeOrdered(tensors))
    assert plan.placements["a"].offset == plan.placements["b"].offset
    assert plan.arena_bytes == 2 * 1024   # two class-1024 slots
    # internal padding is charged to utilization via requested bytes
    assert plan.placements["b"].requested == 960  # 900 aligned to 64
    assert plan.placements["b"].nbytes == 1024


def test_buddy_coalesces_freed_halves_into_one_larger_block():
    # two adjacent 1K blocks expire, then a 2K request arrives: buddy
    # merges the halves; Algorithm 2 (no coalescing) must extend instead
    tensors = [_t("a", 1024, (0, 10)), _t("b", 1024, (0, 10)),
               _t("big", 2048, (20, 30))]
    buddy = BuddyPlanner().plan(_FakeOrdered(tensors))
    assert buddy.arena_bytes == 2048      # big reuses the coalesced pair
    sorting = SortingPlanner().plan(_FakeOrdered(
        [_t("a", 1024, (0, 10)), _t("b", 1024, (0, 10)),
         _t("big", 2048, (20, 30))]))
    assert sorting.arena_bytes == 4096    # no slot fits 2K: arena extends


def test_buddy_offsets_are_block_aligned():
    tensors = [_t(f"t{i}", 3000 * (i + 1), (i, i + 40)) for i in range(6)]
    plan = BuddyPlanner().plan(_FakeOrdered(tensors))
    plan.validate()
    for p in plan.placements.values():
        assert p.offset % p.nbytes == 0   # buddy invariant: natural alignment


# ---------------------------------------------------------------------------
# ALIGN validation contract
# ---------------------------------------------------------------------------

def test_validate_rejects_unaligned_placement():
    plan = Plan({"x": Placement("x", 32, 64, 0, 1)}, 128, "sorting")
    with pytest.raises(AssertionError, match="ALIGN"):
        plan.validate()


@pytest.mark.parametrize("planner", sorted(PLANNERS))
def test_all_planners_emit_aligned_offsets(planner):
    # ragged sizes everywhere: alignment must still hold for every planner
    tensors = [_t(f"t{i}", 777 * (i + 1), (i % 5, i % 5 + 10 + i))
               for i in range(12)]
    plan = get_planner(planner).plan(_FakeOrdered(tensors))
    plan.validate()
    assert all(p.offset % ALIGN == 0 for p in plan.placements.values())


# ---------------------------------------------------------------------------
# Swap-aware same-offset pass + in-place prefetch elision
# ---------------------------------------------------------------------------

def test_inplace_prefetch_when_gap_unused():
    """A swapped tensor whose vacated bytes nobody touches keeps its data
    in place: same offset, no host slot, no DMA."""
    big = _t("X:big", 1 << 20, (0, 50))
    ordered = _FakeOrdered([big])
    sched = plan_offload(ordered, min_idle_phases=30, min_bytes=1)
    assert sched.names() == ("X:big",)
    plan = plan_memory_swapped(ordered, sched)
    assert plan.inplace == ("X:big",)
    assert plan.inplace_prefetch_count == 1
    (d,) = plan.schedule.decisions
    assert d.inplace
    assert plan.schedule.dma_bytes == 0
    assert plan.host_pool_bytes == 0          # no host slot at all
    pre, post = sorted(plan.residencies["X:big"], key=lambda r: r.min_eo)
    assert pre.offset == post.offset
    # the bytes never left, so the residency bound covers the full span
    assert plan.activation_residency_peak() == 1 << 20


def test_no_elision_when_gap_bytes_are_reused():
    """When another tensor occupies the vacated bytes, the swap must move
    data: host slot + DMA stay, even at the same device offset."""
    big = _t("X:big", 1 << 20, (0, 50))
    mid = _t("X:mid", 1 << 20, (10, 20))   # lives inside big's idle window
    ordered = _FakeOrdered([big, mid])
    sched = plan_offload(ordered, min_idle_phases=30, min_bytes=1)
    assert sched.names() == ("X:big",)
    plan = plan_memory_swapped(ordered, sched)
    assert plan.arena_bytes == 1 << 20     # mid reuses big's vacated bytes
    assert plan.inplace == ()
    assert plan.schedule.dma_bytes == 2 * (1 << 20)
    assert plan.host_pool_bytes == 1 << 20


def test_same_offset_pass_reanchors_bestfit_split():
    """BestFit places split residencies independently; the pass must pull
    the post interval back to the pre offset when that space is free."""
    cp = compile_plan(
        ZOO["resnet18"](),
        MemoryPlanConfig(planner="bestfit", min_idle_phases=3,
                         min_bytes=1 << 12), batch=8)
    cp.plan.validate()
    same = sum(
        1 for name in cp.swapped_names()
        for rs in [sorted(cp.plan.residencies[name], key=lambda r: r.min_eo)]
        if rs[0].offset == rs[1].offset)
    assert same > 0, "no pre/post pair shares an offset"
    # tie-breaking yields in-place prefetches at equal-or-better peak
    assert cp.inplace_prefetch_count > 0
    assert cp.peak_bytes <= cp.coopt.single_pass_peak_bytes
    assert cp.peak_bytes <= cp.baseline.arena_bytes


def test_validation_catches_forged_inplace():
    big = _t("X:big", 1 << 20, (0, 50))
    mid = _t("X:mid", 1 << 20, (10, 20))
    ordered = _FakeOrdered([big, mid])
    sched = plan_offload(ordered, min_idle_phases=30, min_bytes=1)
    plan = plan_memory_swapped(ordered, sched)
    # claim the swap was in-place although mid used its bytes
    forged = dataclasses.replace(
        plan, inplace=("X:big",),
        schedule=make_schedule(tuple(
            dataclasses.replace(d, inplace=True)
            for d in plan.schedule.decisions)))
    with pytest.raises(AssertionError):
        forged.validate()


def test_make_schedule_excludes_inplace_from_aggregates():
    d_move = OffloadDecision(name="X:a", nbytes=1 << 20, write_eo=0,
                             read_eo=50, prefetch_at_eo=48)
    d_inpl = dataclasses.replace(
        OffloadDecision(name="X:b", nbytes=1 << 20, write_eo=0,
                        read_eo=50, prefetch_at_eo=48), inplace=True)
    sched = make_schedule((d_move, d_inpl))
    assert len(sched.decisions) == 2       # both stay in the schedule
    assert sched.hbm_bytes_saved == 1 << 20
    assert sched.dma_bytes == 2 * (1 << 20)
    assert sched.peak_inflight_prefetch == 1 << 20


# ---------------------------------------------------------------------------
# Host pool: packed by its own allocator, strictly below the legacy bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hp", ["segregated", "buddy"])
def test_host_pool_strictly_below_legacy_pack_every_copy(hp):
    """The fragmentation-aware host pool must strictly beat the legacy
    behaviour (a SortingPlanner pack over EVERY offloaded copy — what the
    code charged before the allocator layer) on resnet18: the in-place
    elision removes whole copies from the pool."""
    from repro.core.planner import legacy_host_pool_bytes

    cp = compile_plan(
        ZOO["resnet18"](),
        MemoryPlanConfig(planner="bestfit", host_planner=hp,
                         min_idle_phases=3, min_bytes=1 << 12), batch=8)
    legacy = legacy_host_pool_bytes(cp.ordered, cp.schedule)
    assert cp.inplace_prefetch_count > 0
    assert cp.host_pool_bytes < legacy
    # and the executor-visible DMA shrinks with it
    assert cp.dma_bytes == 2 * sum(
        d.nbytes for d in cp.schedule.decisions if not d.inplace)


@pytest.mark.parametrize("hp", ["sorting", "bestfit", "segregated", "buddy"])
@pytest.mark.parametrize("name", sorted(ZOO))
def test_every_zoo_model_compiles_with_every_host_planner(name, hp):
    """Acceptance sweep: the full zoo × host-planner matrix produces valid
    plans (single-pass: the co-optimisation loop is covered elsewhere)."""
    cp = compile_plan(
        ZOO[name](),
        MemoryPlanConfig(host_planner=hp, min_idle_phases=3,
                         min_bytes=1 << 12, cooptimize=False), batch=8)
    cp.plan.validate()
    # (no peak <= baseline claim here: that is the co-optimisation loop's
    # guarantee, deliberately off in this sweep to keep the matrix cheap)
    assert cp.peak_bytes > 0
    r = cp.report()
    assert r["host_planner"] == hp
    assert r["host_pool_bytes"] >= 0
    # lowered transfer ops must be consistent with the schedule
    moving = [d for d in cp.schedule.decisions
              if d.vacates and not d.inplace and d.name.startswith("X:")]
    assert len(cp.lowered.transfers()) == 2 * len(moving)


def test_host_pool_never_below_peak_live_lower_bound():
    # sanity: no packer may "win" by under-provisioning the host pool
    for hp in ("sorting", "bestfit", "segregated", "buddy"):
        cp = compile_plan(
            ZOO["vgg16"](),
            MemoryPlanConfig(planner="bestfit", host_planner=hp,
                             min_idle_phases=3, min_bytes=1 << 12), batch=8)
        host = cp.plan.host
        host.validate()
        live = 0
        events = {p.min_eo for p in host.placements.values()} \
            | {p.max_eo for p in host.placements.values()}
        for eo in events:
            live = max(live, sum(p.live_bytes
                                 for p in host.placements.values()
                                 if p.min_eo <= eo <= p.max_eo))
        assert cp.host_pool_bytes >= live
