"""Layer-basis executor vs whole-graph autodiff (paper §5.1 correctness gate:
'if a weight or activation value has an error over 1e-4, the commit is
rejected' — we assert 1e-4 relative as well)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inplace
from repro.core.planned_exec import (init_params, planned_loss_and_grads,
                                     reference_loss_and_grads, sgd_update)
from repro.core.zoo import ZOO

# NOTE: do not mutate global jax.config at import time here — x64-off is the
# JAX default, and an import-time update leaks into every other test module
# collected in the same process.


def _tree_allclose(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def _make_batch(graph, batch, rng, integer_input=False):
    kx, ky = jax.random.split(rng)
    if integer_input:
        x = jax.random.randint(kx, (batch,) + tuple(graph.input_shape), 0, 100)
    else:
        x = jax.random.normal(kx, (batch,) + tuple(graph.input_shape))
    y = jax.random.normal(ky, (batch,) + tuple(graph.label_shape))
    return x, y


SMALL_CASES = [
    ("model_a_linear", False),
    ("model_b_linear", False),
    ("model_c_linear", False),
    ("model_d", False),
    ("lenet5", False),
]


def _shrink(graph):
    """Shrink 150528-wide test graphs so CPU tests stay fast."""
    for l in graph.layers:
        a = l.attrs
        if a.get("in_features") == 150528:
            a["in_features"] = 96
    if graph.input_shape == (150528,):
        graph.layers  # keep structure
        object.__setattr__(graph, "input_shape", (96,))
    from repro.core.graph import infer_shapes
    infer_shapes(graph)
    return graph


@pytest.mark.parametrize("name,int_in", SMALL_CASES)
def test_planned_grads_match_autodiff(name, int_in):
    g = _shrink(ZOO[name]())
    rng = jax.random.PRNGKey(0)
    params = init_params(g, rng)
    x, y = _make_batch(g, 4, jax.random.PRNGKey(1), int_in)
    if name == "lenet5":
        y = jax.nn.one_hot(jnp.argmax(y, -1), y.shape[-1])
    loss_p, grads_p = planned_loss_and_grads(g, params, x, y)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_p), float(loss_r), rtol=1e-5)
    _tree_allclose(grads_p, grads_r)


def test_unrolled_tacotron_grads_match_scan_autodiff():
    """E-shared unrolled LSTM: accumulated grads == autodiff over the whole
    unrolled graph (weights tied)."""
    g = ZOO["tacotron2_decoder"](time_steps=4, mel_dim=8, prenet_dim=8,
                                 lstm_dim=8)
    rng = jax.random.PRNGKey(0)
    params = init_params(g, rng)
    x, y = _make_batch(g, 2, jax.random.PRNGKey(1))
    loss_p, grads_p = planned_loss_and_grads(g, params, x, y)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    np.testing.assert_allclose(float(loss_p), float(loss_r), rtol=1e-5)
    _tree_allclose(grads_p, grads_r)


def test_transfer_learning_only_updates_head():
    g = _shrink(ZOO["model_b_linear"]())
    from repro.core.graph import slice_realizer
    g = slice_realizer(g, freeze_until="fc0__act")
    params = init_params(g, jax.random.PRNGKey(0))
    x, y = _make_batch(g, 4, jax.random.PRNGKey(1))
    loss, grads = planned_loss_and_grads(g, params, x, y)
    assert "fc0" not in grads and "fc1" in grads
    new = sgd_update(params, grads)
    np.testing.assert_allclose(np.asarray(new["fc0"]["w"]),
                               np.asarray(params["fc0"]["w"]))
    assert not np.allclose(np.asarray(new["fc1"]["w"]),
                           np.asarray(params["fc1"]["w"]))


def test_training_reduces_loss():
    g = _shrink(ZOO["model_b_linear"]())
    params = init_params(g, jax.random.PRNGKey(0))
    x, y = _make_batch(g, 16, jax.random.PRNGKey(1))
    first = None
    for _ in range(30):
        loss, grads = planned_loss_and_grads(g, params, x, y)
        if first is None:
            first = float(loss)
        params = sgd_update(params, grads, lr=0.05)
    assert float(loss) < first * 0.7


# ---------------------------------------------------------------------------
# In-place activation calculus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn", ["sigmoid", "tanh", "relu", "softmax"])
def test_inplace_vjp_matches_standard(fn):
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
    act = inplace.make_inplace_act(fn)
    ref = {"sigmoid": jax.nn.sigmoid, "tanh": jnp.tanh,
           "relu": lambda v: jnp.maximum(v, 0.0),
           "softmax": lambda v: jax.nn.softmax(v, axis=-1)}[fn]

    def f_in(v):
        return jnp.sum(jnp.sin(act(v) * 3.0))

    def f_ref(v):
        return jnp.sum(jnp.sin(ref(v) * 3.0))

    np.testing.assert_allclose(np.asarray(jax.grad(f_in)(x)),
                               np.asarray(jax.grad(f_ref)(x)),
                               rtol=1e-5, atol=1e-6)


def test_inplace_batchnorm_matches_standard():
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 10))
    gamma = jnp.ones((10,)) * 1.3
    beta = jnp.ones((10,)) * 0.2

    def ref_bn(x, gamma, beta, eps=1e-5):
        mean = jnp.mean(x, axis=0, keepdims=True)
        var = jnp.var(x, axis=0, keepdims=True)
        return gamma * (x - mean) * jax.lax.rsqrt(var + eps) + beta

    def f_in(x, g, b):
        return jnp.sum(inplace.batchnorm(x, g, b) ** 2)

    def f_ref(x, g, b):
        return jnp.sum(ref_bn(x, g, b) ** 2)

    g_in = jax.grad(f_in, argnums=(0, 1, 2))(x, gamma, beta)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, gamma, beta)
    for a, b in zip(g_in, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_inplace_act_residual_is_output():
    """Structural check: the VJP residual of the in-place sigmoid is its
    output (input buffer not kept alive)."""
    x = jnp.ones((4, 4))
    y, vjp_fn = jax.vjp(inplace.sigmoid, x)
    # pull the residuals out of the vjp closure: for custom_vjp they are the
    # fwd function's returned residuals; reconstructing dy*y*(1-y) must match
    (dx,) = vjp_fn(jnp.ones_like(y))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(y * (1 - y)),
                               rtol=1e-6)
