"""Static dependence analysis + fusion-legality prover (repro.core.verify.deps).

The provers' contract, exercised from both sides:

* soundness — random *dependence-preserving* permutations of a lowered
  schedule are accepted by ``schedules_equivalent``; breaking any single
  edge (adjacent swap against the DAG) is rejected with that edge's
  check id; op-stream multiset drift is ``dep_stream``;
* fusion — ``plan_fusion`` blocks are structurally well-formed, cross no
  transfer fence, and their ``replay_stream`` is proven equivalent on
  every zoo model; ``verify_fusion`` independently rejects forged plans
  (fence / hazard / peak);
* the consumer — the ``jit_blocks`` backend matches whole-graph
  ``jax.grad`` to the paper's 1e-4 gate on every zoo model while
  dispatching strictly fewer Python-level calls than ops, and its
  replayed stream is the proven permutation, sanitizer-clean;
* plumbing — ``report()["deps"]``, per-check wall time on both verify
  paths, per-transfer slack, and the dispatch-reduction floor on the
  llama3.2-3b MLP trunk.
"""

import random
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import (Compute, ExecutionSchedule, MemoryPlanConfig,
                             Prefetch, SwapOut, compile_plan)
from repro.core.verify import (CHECKS, FusedBlock, FusionPlan,
                               ScheduleVerificationError,
                               build_dependence_graph, check_deps,
                               plan_fusion, replay_stream,
                               schedules_equivalent, transfer_slack,
                               verify_fusion)
from repro.core.zoo import ZOO, transformer_mlp_stack

DEPS_CFG = MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                            min_idle_phases=3, min_bytes=1 << 12,
                            cooptimize=False)

_HEAVY = {"vgg16", "resnet18"}
ZOO_CASES = [
    pytest.param(name, marks=pytest.mark.slow) if name in _HEAVY
    else name
    for name in sorted(ZOO)
]


def _shrink(graph):
    for l in graph.layers:
        if l.attrs.get("in_features") == 150528:
            l.attrs["in_features"] = 96
    if graph.input_shape == (150528,):
        object.__setattr__(graph, "input_shape", (96,))
    from repro.core.graph import infer_shapes
    infer_shapes(graph)
    return graph


def _batch_for(g, batch=2):
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    if any(l.kind == "embedding" for l in g.layers):
        x = jax.random.randint(kx, (batch,) + tuple(g.input_shape), 0, 50)
    else:
        x = jax.random.normal(kx, (batch,) + tuple(g.input_shape))
    y = jax.random.normal(ky, (batch,) + tuple(g.label_shape))
    if g.layers[-1].kind == "loss_ce":
        y = jax.nn.one_hot(jnp.argmax(y, -1), y.shape[-1])
    return x, y


@pytest.fixture(scope="module")
def lenet_cp():
    cp = compile_plan(ZOO["lenet5"](), DEPS_CFG, batch=8)
    assert cp.lowered.transfers(), "reference plan must move data"
    return cp


# ---------------------------------------------------------------------------
# Dependence graph construction
# ---------------------------------------------------------------------------

def test_graph_covers_all_edge_families(lenet_cp):
    g = build_dependence_graph(lenet_cp.lowered, lenet_cp.ordered,
                               lenet_cp.plan)
    counts = g.edge_counts()
    assert counts["data"] > 0 and counts["fence"] > 0 and counts["reuse"] > 0
    assert len(g.ops) == len(lenet_cp.lowered.ops)
    # every edge is within bounds and non-reflexive
    for e in g.edges:
        assert 0 <= e.src < len(g.ops) and 0 <= e.dst < len(g.ops)
        assert e.src != e.dst


def test_clean_schedule_is_its_own_linear_extension(lenet_cp):
    g = build_dependence_graph(lenet_cp.lowered, lenet_cp.ordered,
                               lenet_cp.plan)
    assert g.check_order(lenet_cp.lowered.ops) == []
    rep = schedules_equivalent(lenet_cp.lowered, lenet_cp.lowered,
                               ordered=lenet_cp.ordered, plan=lenet_cp.plan)
    assert rep.ok and rep.checks_run == ("deps",)
    assert rep.check_seconds["deps"] >= 0.0


def test_check_deps_registered():
    assert CHECKS["deps"] is check_deps


def _topo_permutations(ops, edges, rng, n):
    """Random linear extensions of the dependence DAG (Kahn + shuffle)."""
    succ = {}
    indeg = [0] * len(ops)
    for e in edges:
        succ.setdefault(e.src, []).append(e.dst)
        indeg[e.dst] += 1
    out = []
    for _ in range(n):
        deg = list(indeg)
        ready = [i for i, d in enumerate(deg) if d == 0]
        order = []
        while ready:
            i = ready.pop(rng.randrange(len(ready)))
            order.append(i)
            for j in succ.get(i, ()):
                deg[j] -= 1
                if deg[j] == 0:
                    ready.append(j)
        assert len(order) == len(ops), "dependence DAG has a cycle"
        out.append(tuple(ops[i] for i in order))
    return out


def test_dependence_preserving_permutations_accepted(lenet_cp):
    g = build_dependence_graph(lenet_cp.lowered, lenet_cp.ordered,
                               lenet_cp.plan)
    rng = random.Random(0)
    perms = _topo_permutations(g.ops, g.edges, rng, 10)
    assert any(p != lenet_cp.lowered.ops for p in perms), \
        "sampler only produced the identity order"
    for p in perms:
        rep = schedules_equivalent(lenet_cp.lowered, p,
                                   ordered=lenet_cp.ordered,
                                   plan=lenet_cp.plan)
        assert rep.ok, [d.render() for d in rep.errors()]


def test_edge_breaking_swaps_rejected(lenet_cp):
    """Inverting any sampled dependence edge must fail with its check id."""
    g = build_dependence_graph(lenet_cp.lowered, lenet_cp.ordered,
                               lenet_cp.plan)
    ops = list(lenet_cp.lowered.ops)
    rng = random.Random(1)
    sampled = rng.sample(list(g.edges), min(12, len(g.edges)))
    tried = 0
    for e in sampled:
        # move the edge's source to just after its destination
        mutated = list(ops)
        src_op = mutated.pop(e.src)
        dst_pos = mutated.index(g.ops[e.dst])
        mutated.insert(dst_pos + 1, src_op)
        if tuple(mutated) == tuple(ops):
            continue
        tried += 1
        rep = schedules_equivalent(lenet_cp.lowered, tuple(mutated),
                                   ordered=lenet_cp.ordered,
                                   plan=lenet_cp.plan)
        assert not rep.ok, e
        assert e.check in rep.check_ids(), (e, sorted(rep.check_ids()))
    assert tried >= 8


def test_dropped_and_invented_ops_are_dep_stream(lenet_cp):
    ops = lenet_cp.lowered.ops
    dropped = ops[:-1]
    rep = schedules_equivalent(lenet_cp.lowered, dropped,
                               ordered=lenet_cp.ordered, plan=lenet_cp.plan)
    assert not rep.ok and "dep_stream" in rep.check_ids()
    duplicated = ops + (ops[-1],)
    rep = schedules_equivalent(lenet_cp.lowered, duplicated,
                               ordered=lenet_cp.ordered, plan=lenet_cp.plan)
    assert not rep.ok and "dep_stream" in rep.check_ids()


def test_equivalence_without_plan_context(lenet_cp):
    """The prover degrades gracefully with no plan: data+fence edges only."""
    rep = schedules_equivalent(lenet_cp.lowered, lenet_cp.lowered)
    assert rep.ok
    swapped = list(lenet_cp.lowered.ops)
    pf = next(i for i, o in enumerate(swapped) if isinstance(o, Prefetch))
    c = next(i for i, o in enumerate(swapped)
             if isinstance(o, Compute) and o.eo == swapped[pf].read_eo)
    swapped.insert(pf, swapped.pop(c))
    rep = schedules_equivalent(lenet_cp.lowered, tuple(swapped))
    assert not rep.ok and "dep_transfer_fence" in rep.check_ids()


# ---------------------------------------------------------------------------
# Mutation-harness contracts (tools/mutate_schedule.py)
# ---------------------------------------------------------------------------

def _tools():
    import pathlib
    import sys
    tools = pathlib.Path(__file__).resolve().parent.parent / "tools"
    if str(tools) not in sys.path:
        sys.path.insert(0, str(tools))
    import mutate_schedule
    return mutate_schedule


def test_mutation_hoist_compute_fires_fence(lenet_cp):
    from repro.core.verify import verify_schedule
    m = _tools()
    forged = ExecutionSchedule(
        ops=m.mutate_hoist_compute(lenet_cp.lowered.ops))
    rep = verify_schedule(lenet_cp.ordered, lenet_cp.schedule,
                          lenet_cp.plan, forged)
    assert not rep.ok and "dep_transfer_fence" in rep.check_ids()


def test_mutation_drop_dep_edge_fires_dep_edge(lenet_cp):
    from repro.core.verify import verify_schedule
    m = _tools()
    forged = ExecutionSchedule(
        ops=m.mutate_drop_dep_edge(lenet_cp.lowered.ops))
    rep = verify_schedule(lenet_cp.ordered, lenet_cp.schedule,
                          lenet_cp.plan, forged)
    assert not rep.ok and "dep_edge" in rep.check_ids()


def test_mutation_fuse_across_swap_fires_fusion_fence(lenet_cp):
    m = _tools()
    fusion = m.forge_illegal_fusion(lenet_cp)
    diags = verify_fusion(fusion, lenet_cp.lowered, lenet_cp.ordered,
                          lenet_cp.plan)
    assert any(d.check == "fusion_fence" and d.severity == "error"
               for d in diags)


# ---------------------------------------------------------------------------
# Fusion planning + independent re-proof
# ---------------------------------------------------------------------------

def test_fusion_plan_structure(lenet_cp):
    ops = lenet_cp.lowered.ops
    fp = plan_fusion(lenet_cp.lowered, lenet_cp.ordered, lenet_cp.plan)
    seen = set()
    for b in fp.blocks:
        assert len(b.compute_indices) >= 2          # min_block
        assert set(b.op_indices) \
            == set(b.compute_indices) | set(b.free_indices)
        assert not seen & set(b.op_indices), "blocks must be disjoint"
        seen |= set(b.op_indices)
        for i in b.compute_indices:
            assert isinstance(ops[i], Compute)
        # no transfer inside the block span
        lo, hi = b.span()
        assert not any(isinstance(ops[i], (SwapOut, Prefetch))
                       for i in range(lo, hi + 1)), b
    s = fp.summary()
    assert s["dispatch_calls"] == fp.dispatch_calls() < len(ops)
    assert s["fused_computes"] == fp.fused_computes() <= s["n_computes"]


@pytest.mark.parametrize("name", ZOO_CASES)
def test_fusion_replay_equivalent_on_zoo(name):
    g = _shrink(ZOO[name]())
    cp = compile_plan(g, DEPS_CFG, batch=2)
    fp = plan_fusion(cp.lowered, cp.ordered, cp.plan)
    assert not any(d.severity == "error"
                   for d in verify_fusion(fp, cp.lowered, cp.ordered,
                                          cp.plan))
    stream = replay_stream(cp.lowered, fp)
    assert Counter(stream) == Counter(cp.lowered.ops)
    rep = schedules_equivalent(cp.lowered, stream, ordered=cp.ordered,
                               plan=cp.plan)
    assert rep.ok, (name, [d.render() for d in rep.errors()])


def test_verify_fusion_rejects_foreign_op_and_peak(lenet_cp):
    ops = lenet_cp.lowered.ops
    # a block spanning a non-member Free is a hazard
    fi = next(i for i, o in enumerate(ops)
              if type(o).__name__ == "Free"
              and isinstance(ops[i - 1], Compute)
              and isinstance(ops[i + 1], Compute))
    block = FusedBlock(index=0, op_indices=(fi - 1, fi + 1),
                       compute_indices=(fi - 1, fi + 1), free_indices=())
    fp = FusionPlan(blocks=(block,), n_ops=len(ops),
                    n_computes=sum(isinstance(o, Compute) for o in ops),
                    fence_splits=0, hazard_splits=0, inplace_splits=0,
                    peak_splits=0)
    diags = verify_fusion(fp, lenet_cp.lowered, lenet_cp.ordered,
                          lenet_cp.plan)
    assert any(d.check == "fusion_hazard" for d in diags)
    # an impossible residency bound flags the legitimate plan too
    good = plan_fusion(lenet_cp.lowered, lenet_cp.ordered, lenet_cp.plan)
    diags = verify_fusion(good, lenet_cp.lowered, lenet_cp.ordered,
                          lenet_cp.plan, peak_bytes=1)
    assert any(d.check == "fusion_peak" for d in diags)


def test_transfer_slack_shape(lenet_cp):
    s = transfer_slack(lenet_cp.lowered)
    assert s["transfers"], "reference plan must have prefetches"
    for t in s["transfers"].values():
        assert t["slack_phases"] == t["read_eo"] - t["prefetch_eo"] >= 0
        assert t["window_computes"] >= 0
    assert s["min_prefetch_slack_phases"] >= 0
    assert (s["mean_prefetch_slack_phases"]
            >= s["min_prefetch_slack_phases"])


# ---------------------------------------------------------------------------
# The first consumer: the jit_blocks executor backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ZOO_CASES)
def test_jit_blocks_matches_jax_grad_on_zoo(name):
    from repro.core.exec.layers import reference_loss_and_grads
    g = _shrink(ZOO[name]())
    batch = 2
    cp = compile_plan(g, dataclasses_replace_executor(DEPS_CFG,
                                                      "jit_blocks"),
                      batch=batch)
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, batch)
    loss_r, grads_r = reference_loss_and_grads(g, params, x, y)
    loss, grads, stats = cp.loss_and_grads(params, x, y)
    np.testing.assert_allclose(float(loss), float(loss_r), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(grads_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    # proven-equivalent permutation, strictly fewer dispatches than ops
    assert Counter(stats.replayed_ops) == Counter(cp.lowered.ops)
    schedules_equivalent(cp.lowered, stats.replayed_ops,
                         ordered=cp.ordered,
                         plan=cp.plan).raise_if_errors()
    assert stats.dispatch_calls < len(cp.lowered.ops), name
    assert stats.late_swap_ins == 0
    assert stats.hbm_high_water <= stats.planned_peak


def dataclasses_replace_executor(cfg, executor):
    import dataclasses
    return dataclasses.replace(cfg, executor=executor)


def test_jit_blocks_replayed_stream_is_the_plans(lenet_cp):
    """The replayed op stream IS replay_stream(plan_fusion(...)) — the
    executor executes exactly the permutation the prover licensed."""
    from repro.core.exec import get_backend
    g = ZOO["lenet5"]()
    cp = compile_plan(g, dataclasses_replace_executor(DEPS_CFG,
                                                      "jit_blocks"),
                      batch=8)
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, 8)
    _, _, stats = cp.loss_and_grads(params, x, y)
    fp = plan_fusion(cp.lowered, cp.ordered, cp.plan)
    assert stats.replayed_ops == replay_stream(cp.lowered, fp)


def test_jit_blocks_sanitizer_clean():
    from repro.core.exec.backends import JitBlocksBackend
    g = ZOO["lenet5"]()
    cp = compile_plan(g, DEPS_CFG, batch=8)
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, 8)
    be = JitBlocksBackend(sanitize=True)
    _, _, stats = be.run(g, params, x, y, schedule=cp.schedule,
                         ordered=cp.ordered, plan=cp.plan,
                         lowered=cp.lowered)
    assert stats.sanitizer_checks == len(cp.lowered.ops)
    rep = be.report()
    assert rep["fusion"]["dispatch_calls"] == stats.dispatch_calls


def test_jit_blocks_iterates_through_fn_cache():
    g = ZOO["lenet5"]()
    cp = compile_plan(g, dataclasses_replace_executor(DEPS_CFG,
                                                      "jit_blocks"),
                      batch=8)
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, 8)
    l1, g1, s1 = cp.loss_and_grads(params, x, y)
    l2, g2, s2 = cp.loss_and_grads(params, x, y)
    assert float(l1) == float(l2)
    assert s1.dispatch_calls == s2.dispatch_calls
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_jit_blocks_refuses_unprovable_fusion(lenet_cp, monkeypatch):
    """If the fused stream fails the equivalence proof, admission raises
    before any op executes."""
    from repro.core.exec import backends as B
    g = ZOO["lenet5"]()
    cp = compile_plan(g, DEPS_CFG, batch=8)
    params = cp.init_params(jax.random.PRNGKey(0))
    x, y = _batch_for(g, 8)
    m = _tools()
    monkeypatch.setattr("repro.core.verify.plan_fusion",
                        lambda *a, **k: m.forge_illegal_fusion(cp))
    be = B.JitBlocksBackend()
    with pytest.raises(ScheduleVerificationError):
        be.run(g, params, x, y, schedule=cp.schedule, ordered=cp.ordered,
               plan=cp.plan, lowered=cp.lowered)


# ---------------------------------------------------------------------------
# Plumbing: report()["deps"], per-check timing, the llama floor
# ---------------------------------------------------------------------------

def test_deps_report_in_plan_report(lenet_cp):
    r = lenet_cp.report()
    d = r["deps"]
    assert d["n_ops"] == len(lenet_cp.lowered.ops)
    assert set(d["edges"]) == {"data", "fence", "reuse"}
    assert d["fusion"]["dispatch_calls"] < d["n_ops"]
    assert d["min_prefetch_slack_phases"] >= 0


def test_deps_knob_off_skips_analysis():
    import dataclasses
    cp = compile_plan(ZOO["lenet5"](),
                      dataclasses.replace(DEPS_CFG, deps=False), batch=8)
    assert cp.deps_report is None
    assert "deps" not in cp.report()


def test_per_check_wall_time_graph_path(lenet_cp):
    v = lenet_cp.report()["verify"]
    assert set(v["check_wall_time_s"]) == set(v["checks_run"])
    assert "deps" in v["check_wall_time_s"]
    assert all(t >= 0.0 for t in v["check_wall_time_s"].values())


def test_per_check_wall_time_model_path():
    from repro.configs import ARCHS
    cp = compile_plan(ARCHS["llama3.2-3b"],
                      MemoryPlanConfig(remat=True,
                                       remat_budget_bytes=1 << 20),
                      batch_tokens=512)
    v = cp.report()["verify"]
    assert v["check_wall_time_s"] == {"budget": v["wall_time_s"]}


def test_llama_mlp_stack_dispatch_reduction():
    """Acceptance floor: the proven fusion plan cuts Python-level dispatch
    calls >= 5x vs per-op dispatch on the llama3.2-3b MLP trunk."""
    g = transformer_mlp_stack()
    cp = compile_plan(
        g, MemoryPlanConfig(planner="bestfit", host_planner="segregated",
                            min_idle_phases=6, min_bytes=1 << 20,
                            cooptimize=False, hbm_budget_bytes=6 << 20),
        batch=32)
    assert cp.lowered.transfers(), "the trunk plan must move data"
    d = cp.deps_report
    reduction = d["n_ops"] / d["fusion"]["dispatch_calls"]
    assert reduction >= 5.0, reduction
    fp = plan_fusion(cp.lowered, cp.ordered, cp.plan)
    assert not any(x.severity == "error"
                   for x in verify_fusion(fp, cp.lowered, cp.ordered,
                                          cp.plan))
    rep = schedules_equivalent(cp.lowered, replay_stream(cp.lowered, fp),
                               ordered=cp.ordered, plan=cp.plan)
    assert rep.ok
